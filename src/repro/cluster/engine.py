"""ShardedEngine: N ``VDMSAsyncEngine`` shards behind one session API.

The paper scales the *remote op pool*; this layer scales the **engine
itself** — metadata store, blob store, result cache, admission ledger
and event loop all partition with their shard (the VDMS deployment
model: independent server instances, data partitioned across them).
``submit()`` returns one :class:`~repro.cluster.gather.ClusterFuture`
and ``execute()`` stays the thin blocking wrapper, so every existing
caller pattern works against a cluster unchanged.

Placement is a consistent-hash ring over entity ids
(:class:`~repro.cluster.ring.HashRing`, ``virtual_nodes`` per shard).
Entity ids are assigned HERE — one cluster-level counter producing the
same ``"{kind}-{n}"`` sequence a single store would — so a
``num_shards=1`` cluster is byte-identical to a plain engine, response
dicts included.  Every stored copy carries its primary's shard id in
the reserved ``_owner`` property; the scatter filters on it (see
``repro.cluster.gather``).

Health & failover: each shard gets a circuit breaker in a
:class:`~repro.query.health.HealthRegistry`.  ``kill_shard`` (or a
breaker opened by repeated sub-query failures, when replicas exist)
marks a shard dead; queries in flight re-drive the dead shard's pieces
on the replica holders with ``replica_factor >= 2``, and fail loudly
with :class:`~repro.distributed.fault.ShardLostError` at
``replica_factor=1``.

Elasticity: ``add_shard()`` / ``remove_shard()`` go through
``ring.rebalance()`` — only the key ranges adjacent to the changed
shard move, planned by
:func:`repro.distributed.elastic.migration_moves` and executed through
the ordinary Add path.  ``cluster_stats()`` exposes per-shard
ownership, imbalance, failover counts, and breaker states.
"""
from __future__ import annotations

import itertools
import threading
from typing import Callable, Optional

from repro.cluster.gather import OWNER_PROP, ClusterFuture, ClusterQuery
from repro.cluster.ring import HashRing
from repro.core.engine import VDMSAsyncEngine
from repro.distributed.elastic import migration_moves
from repro.distributed.fault import ShardLostError
from repro.query.health import HealthRegistry
from repro.query.language import parse_query


class ShardedEngine:
    """A cluster of ``VDMSAsyncEngine`` shards behind the session API.

    Knobs: ``num_shards`` (ring members at construction),
    ``replica_factor`` (copies per entity; 1 = no replication,
    byte-identical single-shard semantics), ``virtual_nodes`` (ring
    points per shard — more vnodes, tighter balance), plus breaker
    parameters (``breaker_*``) for the per-shard health machines.  All
    remaining keyword arguments are forwarded verbatim to every shard's
    ``VDMSAsyncEngine`` constructor."""

    def __init__(self, *, num_shards: int = 2, replica_factor: int = 1,
                 virtual_nodes: int = 64,
                 breaker_failure_threshold: float = 0.5,
                 breaker_min_samples: int = 5,
                 breaker_open_s: float = 1.0,
                 **engine_kwargs):
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards!r}")
        if not 1 <= replica_factor <= num_shards:
            raise ValueError(
                f"replica_factor must be in [1, num_shards={num_shards}], "
                f"got {replica_factor!r} (a replica needs a distinct "
                f"shard to live on)")
        self.replica_factor = replica_factor
        self._engine_kwargs = dict(engine_kwargs)
        self._shards_have_cache = engine_kwargs.get("cache_capacity", 0) > 0
        self.ring = HashRing(range(num_shards), virtual_nodes=virtual_nodes)
        # shards stay in this dict after death so stats remain readable;
        # routing consults _dead + the breakers, never dict membership
        self.shards: dict[int, VDMSAsyncEngine] = {
            sid: VDMSAsyncEngine(**engine_kwargs)
            for sid in range(num_shards)}
        self.health = HealthRegistry(
            [self._bname(sid) for sid in self.shards],
            never_open=(),
            failure_threshold=breaker_failure_threshold,
            min_samples=breaker_min_samples,
            open_s=breaker_open_s)
        self._lock = threading.Lock()
        self._dead: set[int] = set()
        self._eids: dict[str, str] = {}      # eid -> kind (migration reads)
        self._eid_counter = itertools.count()
        self._qid = itertools.count()
        self._queries: dict[str, ClusterQuery] = {}
        self._failovers: dict[int, int] = {}
        self._moved_entities = 0
        self._next_sid = num_shards
        self._shut = False

    @staticmethod
    def _bname(sid) -> str:
        return f"shard:{sid}"

    # ------------------------------------------------------------ ingest
    def _new_eid(self, kind: str) -> str:
        eid = f"{kind}-{next(self._eid_counter)}"
        with self._lock:
            self._eids[eid] = kind
        return eid

    def add_entity(self, kind: str, data, properties: dict) -> str:
        """Ingest one entity: id assigned at the cluster level, copies
        placed on the first ``replica_factor`` live ring owners, every
        copy tagged with the primary's shard id."""
        if self._shut:
            raise RuntimeError("engine is shut down")
        eid = self._new_eid(kind)
        live = self.live_shards()
        owners = [s for s in self.ring_preference(eid)
                  if s in live][: self.replica_factor]
        if not owners:
            raise ShardLostError(f"no live shard to ingest {eid}")
        props = {**properties, OWNER_PROP: owners[0]}
        for sid in owners:
            self.shards[sid].add_entity(kind, data, props, eid=eid)
        return eid

    # ------------------------------------------------------------- query
    def submit(self, query, *,
               on_entity: Optional[Callable] = None,
               cache: bool = True, priority: int = 0,
               timeout_s: Optional[float] = None,
               tenant: str = "") -> ClusterFuture:
        """Submit a VDMS JSON query against the cluster; same contract
        as ``VDMSAsyncEngine.submit`` (future, streaming callbacks,
        cache opt-out, priority, deadline, admission tenant) with the
        scatter/gather and failover semantics of
        ``repro.cluster.gather``."""
        if self._shut:
            raise RuntimeError("engine is shut down")
        cmds = parse_query(query)            # validate before any scatter
        raw_items = [query] if isinstance(query, dict) else list(query)
        raw = []
        for item in raw_items:
            (name, body), = item.items()
            raw.append((name, body))
        qid = str(next(self._qid))
        cq = ClusterQuery(qid, raw, cmds, self, on_entity=on_entity,
                          use_cache=cache, priority=priority,
                          timeout_s=timeout_s, tenant=tenant)
        fut = ClusterFuture(cq)
        with self._lock:
            if self._shut:
                raise RuntimeError("engine is shut down")
            self._queries[qid] = cq
        cq.start()
        exc = cq.sync_overload()
        if exc is not None:
            # same fail-fast contract as the single engine: a shard shed
            # the scatter synchronously, nothing of the query survives
            raise exc
        return fut

    def execute(self, query, timeout: float | None = None, *,
                cache: bool = True) -> dict:
        fut = self.submit(query, cache=cache, timeout_s=timeout)
        try:
            return fut.result(timeout)
        except TimeoutError:
            fut.cancel()                 # drop every shard's work
            raise

    # --------------------------------------------------- gather plumbing
    def _shard_submit(self, sid: int, query, **kw):
        return self.shards[sid].submit(query, **kw)

    def _query_finished(self, qid: str):
        with self._lock:
            self._queries.pop(qid, None)

    def ring_preference(self, eid: str) -> list[int]:
        """Every ring member in this eid's owner-preference order."""
        return self.ring.owners(eid, self.ring.num_shards())

    def next_owner(self, eid: str, exclude) -> int | None:
        """First live shard in ring preference order not in ``exclude``
        — the Add failover target after a holder died mid-ingest."""
        live = self.live_shards()
        for sid in self.ring_preference(eid):
            if sid in live and sid not in exclude:
                return sid
        return None

    # ------------------------------------------------------------ health
    def shard_dead(self, sid: int) -> bool:
        """Killed explicitly, or — only when replicas exist to serve its
        range — marked dead by its breaker.  At ``replica_factor=1`` an
        open breaker stays advisory: skipping the shard would silently
        drop its key range, and a loud per-query error is strictly
        better than quietly incomplete results."""
        if sid in self._dead:
            return True
        if self.replica_factor < 2:
            return False
        b = self.health.get(self._bname(sid))
        return b is not None and not b.routable()

    def live_shards(self) -> list[int]:
        return sorted(s for s in self.shards if not self.shard_dead(s))

    def dead_shards(self) -> list[int]:
        return sorted(s for s in self.shards if self.shard_dead(s))

    def _note_shard_ok(self, sid: int):
        self.health.record_success(self._bname(sid))

    def _note_shard_failure(self, sid: int):
        self.health.record_failure(self._bname(sid))

    def _note_failover(self, sid: int):
        with self._lock:
            self._failovers[sid] = self._failovers.get(sid, 0) + 1

    def kill_shard(self, sid: int):
        """Hard-kill one shard (fault injection / ungraceful death): its
        engine shuts down mid-flight; in-flight pieces re-drive on the
        replica holders (``replica_factor >= 2``) or fail loudly."""
        if sid not in self.shards:
            raise ValueError(f"unknown shard {sid!r}")
        with self._lock:
            self._dead.add(sid)       # marked dead BEFORE the teardown:
        # pieces cancelled by the shutdown classify as failover, not error
        self.shards[sid].shutdown()

    # --------------------------------------------------------- elasticity
    def add_shard(self) -> int:
        """Join a fresh shard: ring rebalance + minimal migration via
        the ordinary Add path.  Returns the new shard id."""
        if self._shut:
            raise RuntimeError("engine is shut down")
        with self._lock:
            sid = self._next_sid
            self._next_sid += 1
        self.shards[sid] = VDMSAsyncEngine(**self._engine_kwargs)
        self.health.register(self._bname(sid))
        delta = self.ring.rebalance(add=sid)
        self._migrate(delta)
        return sid

    def remove_shard(self, sid: int):
        """Graceful leave: migrate this shard's ranges to the survivors
        (reading from it while it still serves), then shut it down.  A
        dead shard cannot leave gracefully — its ranges already live on
        the replicas, so just leave it killed."""
        if sid not in self.shards:
            raise ValueError(f"unknown shard {sid!r}")
        if sid in self._dead:
            raise ValueError(
                f"shard {sid!r} is dead; graceful removal reads from the "
                f"leaving shard (its replicas already serve its range)")
        if len(self.shards) - 1 < self.replica_factor:
            raise ValueError(
                f"cannot drop below replica_factor={self.replica_factor} "
                f"shards")
        delta = self.ring.rebalance(remove=sid)
        self._migrate(delta)
        eng = self.shards.pop(sid)
        self.health.remove(self._bname(sid))
        with self._lock:
            self._dead.discard(sid)
        eng.shutdown()

    def _migrate(self, delta):
        """Execute a rebalance plan: copy each moved key from a
        surviving holder to its new owners (the existing Add path, so
        ingest invariants hold), re-tag primaries, drop shed copies."""
        rf = self.replica_factor
        with self._lock:
            eids = dict(self._eids)
        moves = migration_moves(
            eids, lambda k: delta.old_owners(k, rf),
            lambda k: delta.new_owners(k, rf))
        moved = 0
        for mv in moves:
            src = next((s for s in delta.old_owners(mv.key, rf)
                        if s in self.shards and s not in self._dead
                        and mv.key in self.shards[s].store), None)
            if src is None:
                continue               # no surviving copy to read from
            holder = self.shards[src]
            data = holder.store.get(mv.key)
            props = holder.meta.get(mv.key)
            props[OWNER_PROP] = mv.new_primary
            for sid in mv.copy_to:
                self.shards[sid].add_entity(eids[mv.key], data, props,
                                            eid=mv.key)
                moved += 1
            if mv.primary_changed:
                for sid in delta.new_owners(mv.key, rf):
                    if sid not in mv.copy_to and sid in self.shards:
                        self.shards[sid].meta.update(
                            mv.key, {OWNER_PROP: mv.new_primary})
            for sid in mv.drop_from:
                if sid not in self.shards:
                    continue
                shard = self.shards[sid]
                shard.meta.remove(mv.key)
                shard.store.delete(mv.key)
                if shard.result_cache is not None:
                    shard.result_cache.invalidate(mv.key)
        with self._lock:
            self._moved_entities += moved

    # ------------------------------------------------------------- stats
    def cluster_stats(self) -> dict:
        """Per-shard ownership/holding, imbalance (max/mean primary
        ownership over live shards), failover counts, migration volume,
        and breaker states."""
        with self._lock:
            eids = list(self._eids)
            failovers = dict(self._failovers)
            moved = self._moved_entities
        owned = self.ring.ownership(eids, n=1)
        live = set(self.live_shards())
        per_shard = {}
        for sid, eng in sorted(self.shards.items()):
            per_shard[sid] = {
                "live": sid in live,
                "owned": owned.get(sid, 0),
                "held": eng.meta.count(),
            }
        live_counts = [per_shard[s]["owned"] for s in sorted(live)]
        mean = sum(live_counts) / len(live_counts) if live_counts else 0.0
        imbalance = (max(live_counts) / mean
                     if live_counts and mean > 0 else 1.0)
        return {
            "num_shards": len(self.shards),
            "live_shards": sorted(live),
            "replica_factor": self.replica_factor,
            "virtual_nodes": self.ring.virtual_nodes,
            "entities": len(eids),
            "per_shard": per_shard,
            "imbalance": imbalance,
            "failovers": failovers,
            "failovers_total": sum(failovers.values()),
            "moved_entities": moved,
            "breakers": self.health.stats(),
        }

    def admission_stats(self) -> dict:
        """Per-shard admission ledgers (leak checks sum across shards)."""
        return {sid: eng.admission_stats()
                for sid, eng in sorted(self.shards.items())}

    def active_queries(self) -> int:
        with self._lock:
            return len(self._queries)

    # ---------------------------------------------------------- teardown
    def shutdown(self):
        """Deterministic teardown: refuse new submits, cancel live
        cluster queries (their shard pieces drop everywhere), then shut
        every shard.  Idempotent."""
        with self._lock:
            self._shut = True
            live = list(self._queries.values())
        for cq in live:
            cq.cancel()
        for sid, eng in list(self.shards.items()):
            if sid not in self._dead:
                eng.shutdown()
