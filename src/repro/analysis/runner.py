"""Orchestration: harvest -> checks -> waivers -> baseline.

``run_analysis`` is the programmatic entry point (the CLI in
``__main__`` and ``tests/test_analysis.py`` both go through it).  The
flow: collect ``.py`` files, harvest each, run the four check
families, apply inline waivers (marking each as used), then convert
every *unused* waiver into a ``useless-waiver`` finding so stale
waivers cannot accumulate.

Baselines: ``analysis_baseline.json`` holds the fingerprints of
accepted findings.  ``check_baseline`` partitions current findings
into new vs. baselined and also reports stale baseline entries
(fingerprints that no longer fire), so the file can be kept tight.
"""
from __future__ import annotations

import dataclasses
import json
import os

from repro.analysis.guards import GuardAnalysis
from repro.analysis.harvest import harvest_module
from repro.analysis.knobs import KNOB_CLASSES, check_knobs
from repro.analysis.locks import LockAnalysis, LockGraph
from repro.analysis.model import RULES, Finding
from repro.analysis.protocols import check_protocols

BASELINE_VERSION = 1


@dataclasses.dataclass
class AnalysisResult:
    findings: list          # live findings, post-waiver
    suppressed: list        # (finding, waiver) pairs
    graph: LockGraph
    files: int

    def to_dict(self) -> dict:
        return {
            "files": self.files,
            "findings": [f.to_dict() for f in self.findings],
            "suppressed": [
                {"finding": f.to_dict(),
                 "waiver_line": w.line, "reason": w.reason}
                for f, w in self.suppressed],
            "lock_graph": {
                "nodes": sorted(self.graph.nodes),
                "edges": [
                    {"src": e.src, "dst": e.dst, "via": e.via,
                     "site": f"{e.path}:{e.line}"}
                    for e in self.graph.edges.values()],
            },
        }


def iter_py_files(paths):
    for p in paths:
        if os.path.isfile(p):
            yield p
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(d for d in dirs
                             if d != "__pycache__" and not d.startswith("."))
            for fn in sorted(files):
                if fn.endswith(".py"):
                    yield os.path.join(root, fn)


def _module_name(path: str) -> str:
    norm = path.replace(os.sep, "/")
    if "/src/" in norm:
        norm = norm.split("/src/", 1)[1]
    elif norm.startswith("src/"):
        norm = norm[4:]
    return norm[:-3].replace("/", ".").lstrip(".")


def _ref_corpus(ref_dirs) -> str:
    chunks = []
    for d in ref_dirs:
        for path in iter_py_files([d]):
            try:
                with open(path, "r", encoding="utf-8") as fh:
                    chunks.append(fh.read())
            except OSError:
                continue
    return "\n".join(chunks)


def run_analysis(paths, *, ref_dirs=(), knob_classes=KNOB_CLASSES,
                 ) -> AnalysisResult:
    modules = []
    findings: list[Finding] = []
    files = 0
    for path in iter_py_files(paths):
        files += 1
        try:
            with open(path, "r", encoding="utf-8") as fh:
                source = fh.read()
        except OSError as e:
            findings.append(Finding(
                rule="parse-error", severity="error", path=path, line=0,
                scope="<module>", subject="unreadable",
                message=f"cannot read: {e}"))
            continue
        mf, err = harvest_module(path, source, _module_name(path))
        if err is not None:
            findings.append(Finding(
                rule="parse-error", severity="error", path=path, line=0,
                scope="<module>", subject="syntax",
                message=f"cannot parse: {err}"))
            continue
        modules.append(mf)

    la = LockAnalysis(modules)
    lock_findings, graph = la.run()
    findings.extend(lock_findings)
    findings.extend(GuardAnalysis(la).run())
    findings.extend(check_knobs(modules, _ref_corpus(ref_dirs),
                                knob_classes))
    findings.extend(check_protocols(la))

    # ------------------------------------------------------- waivers
    waivers = [w for mf in modules for w in mf.waivers]
    by_site = {}
    for w in waivers:
        by_site.setdefault((w.path, w.applies_to, w.rule), []).append(w)
    live: list[Finding] = []
    suppressed: list = []
    for f in findings:
        ws = by_site.get((f.path, f.line, f.rule))
        if ws:
            for w in ws:
                w.used = True
            suppressed.append((f, ws[0]))
        else:
            live.append(f)
    for w in waivers:
        if w.rule not in RULES:
            live.append(Finding(
                rule="useless-waiver", severity="error", path=w.path,
                line=w.line, scope="<module>",
                subject=f"unknown-rule:{w.rule}:{w.source_key}",
                message=f"waiver names unknown rule {w.rule!r} "
                        f"(known: {', '.join(RULES)})"))
        elif not w.used:
            live.append(Finding(
                rule="useless-waiver", severity="error", path=w.path,
                line=w.line, scope="<module>",
                subject=f"{w.rule}:{w.source_key}",
                message=(f"waiver ok({w.rule}) suppresses no finding — "
                         f"remove it (or it is on the wrong line)")))

    live.sort(key=lambda f: (f.path, f.line, f.rule, f.subject))
    return AnalysisResult(findings=live, suppressed=suppressed,
                          graph=graph, files=files)


# ------------------------------------------------------------- baseline
def load_baseline(path: str) -> dict:
    with open(path, "r", encoding="utf-8") as fh:
        data = json.load(fh)
    if data.get("version") != BASELINE_VERSION:
        raise ValueError(f"unsupported baseline version in {path!r}")
    return data


def baseline_fingerprints(data: dict) -> set:
    return {entry["fingerprint"] for entry in data.get("findings", [])}


def write_baseline(path: str, result: AnalysisResult) -> None:
    data = {
        "version": BASELINE_VERSION,
        "comment": ("Accepted pre-existing findings; the CI gate fails "
                    "only on fingerprints not listed here.  Prefer "
                    "fixing or waiving over baselining."),
        "findings": [
            {"fingerprint": f.fingerprint, "rule": f.rule, "path": f.path,
             "scope": f.scope, "subject": f.subject}
            for f in result.findings],
    }
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(data, fh, indent=2, sort_keys=True)
        fh.write("\n")


def check_baseline(result: AnalysisResult,
                   baseline: dict) -> tuple[list, list]:
    """-> (new_findings, stale_fingerprints)."""
    accepted = baseline_fingerprints(baseline)
    current = {f.fingerprint for f in result.findings}
    new = [f for f in result.findings if f.fingerprint not in accepted]
    stale = sorted(accepted - current)
    return new, stale
