"""Remote operation execution (paper section 4.2 + 5.3): an ecosystem of
kappa remote servers with plug-and-play endpoints.

Each ``RemoteServer`` is a worker thread with its own request queue —
the stand-in for a Flask endpoint on another machine.  The transport and
capacity model is explicit and calibrated (ARCHITECTURE.md): a request
costs ``network_latency + payload_bytes/bandwidth + op_service_time``,
realized with real op execution plus a GIL-releasing sleep for the
network/remote-compute component, so overlap measured by the benchmarks
is genuine host-side overlap.

Production features beyond the paper's prototype:
- least-loaded dispatch (in addition to the paper's implicit round-robin);
- straggler mitigation: requests outstanding > ``straggler_factor`` x
  a moving latency estimate are re-issued to another server, first
  response wins (duplicates discarded by request id);
- fault tolerance: a killed server's in-flight requests are re-queued,
  retries capped by ``max_retries``; elastic scale in/out at runtime.
"""
from __future__ import annotations

import dataclasses
import itertools
import queue
import random
import threading
import time
from typing import Any, Callable, Optional

import numpy as np

from repro.core.pipeline import Operation, run_op


@dataclasses.dataclass
class TransportModel:
    """Calibrated cost model for the simulated network + remote compute."""
    network_latency_s: float = 0.002      # per request round trip
    bandwidth_bytes_s: float = 1e9        # payload both ways
    service_time_s: float = 0.0           # extra remote compute per entity
    execute_ops: bool = True              # actually run the op (correctness)

    def cost(self, payload_bytes: int) -> float:
        return self.network_latency_s + 2 * payload_bytes / self.bandwidth_bytes_s \
            + self.service_time_s

    def cost_batch(self, payloads: list[int]) -> float:
        """One request carrying N entities: latency paid once (this is the
        win batched dispatch buys — see ARCHITECTURE.md "coalescing")."""
        return self.network_latency_s + 2 * sum(payloads) / self.bandwidth_bytes_s \
            + self.service_time_s * len(payloads)


@dataclasses.dataclass
class Request:
    rid: int
    entity: Any          # Entity (pointer semantics, paper section 5.1.1)
    op: Operation
    reply_to: queue.Queue
    issued_at: float = 0.0
    attempt: int = 0
    reissues: int = 0


def _batch_size(req: Request) -> int:
    return len(req.entity) if isinstance(req.entity, list) else 1


class RemoteServer:
    def __init__(self, sid: int, transport: TransportModel):
        self.sid = sid
        self.transport = transport
        self.inbox: queue.Queue = queue.Queue()
        self.alive = True
        self.busy = False
        self.processed = 0
        self.transport_busy_s = 0.0   # accumulated cost_batch time
        self._pending = 0             # queued + in-service ENTITIES
        self._pending_lock = threading.Lock()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=f"remote-server-{sid}")
        self._thread.start()

    def submit(self, req: Request):
        with self._pending_lock:
            self._pending += _batch_size(req)
        self.inbox.put(req)

    def _finished(self, req: Request):
        with self._pending_lock:
            self._pending -= _batch_size(req)

    def load(self) -> int:
        # entities, not requests: a k-entity coalesced batch is k units of
        # pending work, so least_loaded dispatch stays balanced when
        # batched and per-entity requests mix
        with self._pending_lock:
            return self._pending

    def kill(self, join_timeout: float | None = 5.0):
        self.alive = False
        self.inbox.put(None)  # wake
        # Join so the worker is not abandoned mid-request (daemon threads
        # racing interpreter teardown). The thread exits promptly: it
        # finishes at most one in-service request, then drains its inbox.
        if join_timeout and self._thread is not threading.current_thread():
            self._thread.join(join_timeout)

    def join(self, timeout: float | None = None):
        self._thread.join(timeout)

    def _run(self):
        while True:
            req = self.inbox.get()
            if req is None:
                if not self.alive:
                    # drain: fail everything left so the pool re-queues it
                    while True:
                        try:
                            r = self.inbox.get_nowait()
                        except queue.Empty:
                            break
                        if r is not None:
                            self._finished(r)
                            r.reply_to.put(("server_died", r, None))
                    return
                continue
            if not self.alive:
                self._finished(req)
                req.reply_to.put(("server_died", req, None))
                continue
            self.busy = True
            try:
                # single path for per-entity and batched requests: the
                # transport cost of a request is ALWAYS cost_batch over
                # its payloads (cost_batch([p]) == cost(p)), never a
                # per-payload cost() sum — one request pays the network
                # latency once, which is the amortization batching buys
                batched = isinstance(req.entity, list)
                ents = req.entity if batched else [req.entity]
                datas = [e.data for e in ents]
                dt = self.transport.cost_batch(
                    [getattr(d, "nbytes", 0) for d in datas])
                self.transport_busy_s += dt
                # network + remote-capacity cost (GIL-releasing)
                time.sleep(dt)
                results = [run_op(req.op, d) if self.transport.execute_ops
                           else d for d in datas]
                for r in results:
                    if r is not None and hasattr(r, "block_until_ready"):
                        r.block_until_ready()
                self.processed += len(results)
                req.reply_to.put(("ok", req,
                                  results if batched else results[0]))
            except Exception as e:  # noqa: BLE001 — report, don't kill worker
                req.reply_to.put(("error", req, e))
            finally:
                self._finished(req)
                self.busy = False


class RemoteServerPool:
    """kappa servers + dispatch policy + retry/straggler logic."""

    def __init__(self, num_servers: int = 1,
                 transport: TransportModel | None = None,
                 policy: str = "round_robin",
                 max_retries: int = 3,
                 straggler_factor: float = 4.0):
        self.transport = transport or TransportModel()
        self.policy = policy
        self.max_retries = max_retries
        self.straggler_factor = straggler_factor
        self.servers: list[RemoteServer] = [
            RemoteServer(i, self.transport) for i in range(num_servers)]
        self._rr = itertools.count()
        self._rid = itertools.count()
        self._lock = threading.Lock()
        self.inflight: dict[int, Request] = {}
        self.dispatched = 0        # requests issued (a batch counts once)
        self.duplicates_dropped = 0
        self.reissued = 0
        self.retried = 0
        self.cancelled_dropped = 0
        self._cancelled_rids: set[int] = set()  # await their late replies
        self._lat_est = self.transport.cost(1 << 20)  # moving latency estimate
        self._lat_samples = 0

    # ---------------------------------------------------------- dispatch
    def _pick(self) -> RemoteServer:
        live = [s for s in self.servers if s.alive]
        if not live:
            raise RuntimeError("no live remote servers")
        if self.policy == "least_loaded":
            return min(live, key=lambda s: s.load())
        return live[next(self._rr) % len(live)]

    def dispatch(self, entity, op: Operation, reply_to: queue.Queue) -> int:
        req = Request(rid=next(self._rid), entity=entity, op=op,
                      reply_to=reply_to, issued_at=time.monotonic())
        with self._lock:
            self.inflight[req.rid] = req
            self.dispatched += 1
        self._pick().submit(req)
        return req.rid

    # --------------------------------------------------------- responses
    def handle_response(self, tag: str, req: Request, payload):
        """Called by the event loop with a server reply.  Returns
        ("done", result) | ("dropped", None) | ("requeued", None)."""
        with self._lock:
            live = req.rid in self.inflight
            if live:
                del self.inflight[req.rid]
            elif req.rid in self._cancelled_rids:
                # late reply for a cancelled query's request: not a
                # straggler duplicate — keep the two stats separate
                self._cancelled_rids.discard(req.rid)
                return ("dropped", None)
        if not live:
            self.duplicates_dropped += 1
            return ("dropped", None)
        if tag == "ok":
            # amortized PER-ENTITY latency: a k-entity batch legitimately
            # takes ~cost_batch longer, and must neither inflate the
            # estimate for per-entity requests nor look like a straggler
            dt = (time.monotonic() - req.issued_at) / _batch_size(req)
            self._lat_est = 0.9 * self._lat_est + 0.1 * dt
            self._lat_samples += 1
            return ("done", payload)
        # failure path: retry on another server
        if req.attempt + 1 >= self.max_retries:
            return ("failed", payload)
        req.attempt += 1
        req.issued_at = time.monotonic()
        with self._lock:
            self.inflight[req.rid] = req
        self._pick().submit(req)
        self.retried += 1
        return ("requeued", None)

    # ------------------------------------------------------- cancellation
    def drop_query(self, query_id: str) -> int:
        """Forget in-flight requests belonging to a cancelled/timed-out
        query.  The server replies still arrive, but ``handle_response``
        no longer finds their rid and drops them — exactly the duplicate-
        suppression path — so nothing is orphaned in ``inflight``.
        Batched requests mixing several queries are kept; the event loop
        filters their per-entity results instead."""

        def _belongs(ent) -> bool:
            if isinstance(ent, list):
                return all(e.query_id == query_id for e in ent)
            return ent.query_id == query_id

        with self._lock:
            doomed = [rid for rid, r in self.inflight.items()
                      if _belongs(r.entity)]
            for rid in doomed:
                del self.inflight[rid]
                self._cancelled_rids.add(rid)
            self.cancelled_dropped += len(doomed)
            if len(self._cancelled_rids) > 100_000:  # lost-reply backstop
                self._cancelled_rids.clear()
        return len(doomed)

    # --------------------------------------------------------- stragglers
    def reissue_stragglers(self):
        """Re-send requests outstanding > straggler_factor x the latency
        estimate.  Guarded: the estimate must have warmed up (first calls
        include jit compilation), and each request is re-issued at most
        once — duplicates are resolved first-response-wins."""
        if self._lat_samples < 8:
            return
        now = time.monotonic()
        # expected wall of a k-entity request = fixed per-request latency
        # + k x amortized per-entity cost; scaling ONLY the per-entity
        # term keeps single requests from looking like stragglers when
        # batched traffic has driven the amortized estimate far below the
        # fixed network latency
        fixed = self.transport.network_latency_s
        with self._lock:
            slow = [r for r in self.inflight.values()
                    if r.reissues == 0
                    and now - r.issued_at > self.straggler_factor
                    * (fixed + max(self._lat_est, 1e-4) * _batch_size(r))]
        for r in slow:
            self.reissued += 1
            r.reissues += 1
            self._pick().submit(r)

    # ------------------------------------------------------------ elastic
    def scale_to(self, n: int):
        """Elastic scale out/in (future-work item (c) of the paper)."""
        while len([s for s in self.servers if s.alive]) < n:
            self.servers.append(RemoteServer(len(self.servers), self.transport))
        live = [s for s in self.servers if s.alive]
        for s in live[n:]:
            # signal only: elastic scale-in must not block the caller
            # through sequential drains (threads are joined at shutdown)
            s.kill(join_timeout=None)

    def kill_server(self, sid: int):
        self.servers[sid].kill()

    def live_count(self) -> int:
        return sum(s.alive for s in self.servers)

    def pending_entities(self) -> int:
        """Entities queued + in service across live servers (the remote
        queue-wait signal the dispatch cost model reads)."""
        return sum(s.load() for s in self.servers if s.alive)

    def latency_estimate(self) -> float:
        """Amortized per-entity latency moving estimate (also feeds the
        dispatch cost model's remote queue-wait term)."""
        return self._lat_est

    def backlog_seconds(self) -> float:
        """Projected seconds of remote work outstanding right now —
        pending entities weighted by the amortized per-entity latency
        estimate, spread over the live servers.  The remote term of the
        admission controller's load score."""
        live = max(1, self.live_count())
        return self.pending_entities() * self._lat_est / live

    def shutdown(self, timeout: float = 5.0):
        for s in self.servers:
            s.kill(join_timeout=None)   # signal everyone first ...
        for s in self.servers:
            s.join(timeout)             # ... then join (parallel drain)
