"""Benchmark harness — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (plus a JSON sidecar with the
full per-row details under experiments/bench/).

  PYTHONPATH=src python -m benchmarks.run            # fast suite
  PYTHONPATH=src python -m benchmarks.run --full     # larger sizes
  PYTHONPATH=src python -m benchmarks.run --only scaleout
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="image|video|cputrace|scaleout|roofline|fusion|"
                         "serving|native_pool|hotpath|dispatch")
    args = ap.parse_args()

    from benchmarks import cpu_trace, image_suite, scaleout, video_suite
    from benchmarks import roofline as roofline_mod

    suites = {}
    if args.full:
        suites["image"] = lambda: (image_suite.run_c1(48)
                                   + image_suite.run_c2(48)
                                   + image_suite.run_c3(24, clients=(2, 4, 8)))
        suites["video"] = lambda: (video_suite.run_c1(6, 8)
                                   + video_suite.run_c2(6, 8)
                                   + video_suite.run_c3(4, 6, clients=(2, 4)))
        # kappa remote-server curve + sharded-cluster shard curve +
        # shard-off identity; also writes repo-root BENCH_scaleout.json
        suites["scaleout"] = lambda: scaleout.run(smoke=False)
    else:
        suites["image"] = lambda: (
            image_suite.run_c1(16, queries=dict(list(
                image_suite.image_queries().items())[:4]))
            + image_suite.run_c2(16) + image_suite.run_c3(8, clients=(2, 4)))
        suites["video"] = lambda: (
            video_suite.run_c1(3, 4, queries=dict(list(
                video_suite.video_queries().items())[:3]))
            + video_suite.run_c2(3, 4) + video_suite.run_c3(2, 3, clients=(2,)))
        suites["scaleout"] = lambda: scaleout.run(smoke=True)
    suites["cputrace"] = lambda: cpu_trace.run()
    from benchmarks import serving_bench
    suites["serving"] = lambda: serving_bench.run()
    suites["native_pool"] = lambda: serving_bench.run_native_pool(
        n_images=48 if args.full else 24,
        sessions=4 if args.full else 2)
    from benchmarks import hotpath
    # also writes repo-root BENCH_hotpath.json (perf trajectory across PRs)
    suites["hotpath"] = lambda: hotpath.run(smoke=not args.full)
    from benchmarks import dispatch_bench
    # also writes repo-root BENCH_dispatch.json (cost-router speedup vs
    # all-native/static + the static-response hash tripwire)
    suites["dispatch"] = lambda: dispatch_bench.run(smoke=not args.full)
    suites["fusion"] = lambda: (
        image_suite.run_c2(16, fuse=False)
        + [dict(r, name=r["name"] + "_fused")
           for r in image_suite.run_c2(16, fuse=True, batch_remote=8)])
    if os.path.isdir("experiments/dryrun_final"):
        suites["roofline"] = roofline_mod.run

    rows = []
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        print(f"# running suite: {name}", file=sys.stderr, flush=True)
        try:
            rows.extend(fn())
        except Exception as e:  # noqa: BLE001 — report and continue
            import traceback
            traceback.print_exc()
            rows.append({"name": f"{name}_FAILED", "us_per_call": -1,
                         "derived": 0.0, "error": str(e)})

    os.makedirs("experiments/bench", exist_ok=True)
    with open("experiments/bench/results.json", "w") as f:
        json.dump(rows, f, indent=2, default=float)

    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']:.4f}")


if __name__ == "__main__":
    main()
