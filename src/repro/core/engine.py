"""VDMS-Async engine: the main thread (Thread_1, paper section 5.1.1).

The client API is *futures-based*: ``submit(query)`` parses the query,
compiles it to a per-query plan (repro.query.planner), launches the first
phase onto the event loop, and returns a :class:`QueryFuture` without
waiting for any operation to execute — submit cost is O(fan-out) pointer
work only (metadata filter + blob-pointer lookups; ~1 ms per 100
entities), never op or network time.  ``execute(query, timeout)``
is kept as a thin blocking wrapper so every existing caller works
unchanged and produces byte-identical responses.

Supports thousands of concurrent in-flight queries (experiment C3 and
beyond): each query is a session with its own fair-queue lane on Queue_1;
the shared event loop — with a configurable native-worker pool —
interleaves entities from all active sessions.  Cancellation/timeout
drops a session's queued and in-flight work instead of orphaning it.
"""
from __future__ import annotations

import itertools
import os
import threading
import time
from typing import Callable, Optional

import numpy as np

from repro.core.entity import ERD, Entity
from repro.core.event_loop import EventLoop
from repro.core.remote import RemoteServerPool, TransportModel
from repro.core.result_cache import ResultCache
from repro.core.session import QueryFuture, QuerySession
from repro.query.admission import AdmissionController, OverloadError
from repro.query.dispatch import (BackendRouter, NativeBackend, OpCostTracker,
                                  RemoteBackend, StaticRouter,
                                  validate_overrides)
from repro.query.health import HealthRegistry
from repro.query.language import parse_query
from repro.query.metadata import MetadataStore
from repro.query.planner import CommandPlan, QueryPlanner
from repro.storage.store import BlobStore


def _default_native_workers() -> int:
    return max(1, min(os.cpu_count() or 1, 8))


class VDMSAsyncEngine:
    """The VDMS-Async query engine: paper-faithful by default, with
    every beyond-paper subsystem behind an explicitly-OFF knob.

    Constructor knobs (grouped; defaults reproduce the paper engine
    except for the scheduling pair, which benchmarks pin explicitly):

    **Remote pool** —
      ``num_remote_servers``: κ simulated remote servers (paper's UDF /
      remote executors), each a worker thread with a calibrated
      transport model.  ``transport``: a
      :class:`~repro.core.remote.TransportModel` (network latency,
      bandwidth, per-entity service time).  ``dispatch_policy``:
      ``"round_robin"`` | ``"least_loaded"`` server picker (NOT the
      multi-backend ``dispatch`` knob below).  ``batch_remote``:
      coalesce up to N same-op entities per remote request.

    **Scheduling** (not paper-faithful by default; the exact paper
    baseline is ``num_native_workers=1, fair_scheduling=False``) —
      ``num_native_workers``: native executor pool size (the paper's
      single Thread_2 generalized; default cpu-bounded).
      ``fair_scheduling``: per-query Queue_1 lanes with round-robin
      service instead of one global FIFO.
      ``fuse_native``: jit-fuse maximal native-op runs.

    **Result cache** (off by default) —
      ``cache_capacity`` / ``cache_capacity_bytes``: bounded LRU keyed
      on (eid, pipeline signature); 0 disables.

    **Cross-session coalescing** (off by default) —
      ``coalesce_window_ms`` / ``coalesce_max_batch``: Thread_3 groups
      pending remote work by op signature ACROSS sessions into one
      batched request per window.

    **Multi-backend dispatch** (static by default) —
      ``dispatch``: ``"static"`` (paper rule, byte-identical) |
      ``"cost"`` (cost-model router) | ``"native"`` (all-native
      baseline).  ``cost_overrides``: ``{op_name: {backend: seconds}}``
      pinned estimates for forced regimes.  ``batcher_group_size`` /
      ``batcher_max_wait_ms``: grouped-UDF backend micro-batching.
      ``device_backend``: build the device-executor backend
      (requires ``dispatch="cost"``): ``True``/``"auto"`` targets jax's
      default device, a platform string (``"cpu"``, ``"gpu"``,
      ``"tpu"``) pins one.  ``device_batch_size`` /
      ``device_max_wait_ms``: device micro-batching window.
      ``device_fuse_segments``: fuse each routed device *segment*
      (maximal run of consecutive device-placed ops) into one
      jit-compiled program — one transfer each way per segment and
      resident intermediates (default on when the device backend is;
      ``False`` reproduces the per-op device path bit-for-bit).
      ``num_device_workers``: device worker count (default: one per
      visible device of the selected platform; > 1 wraps them in a
      :class:`~repro.query.device_backend.MultiDeviceBackend` that
      spreads segment groups by least estimated backlog).

    **Admission control** (off by default) —
      ``admission``: ``"none"`` (accept every ``submit()``
      unconditionally, byte-identical to the unbounded engine) |
      ``"queue"`` (park overflow entities in a priority-ordered pending
      lane drained as capacity frees) | ``"shed"`` (reject queries that
      do not fit with a typed
      :class:`~repro.query.admission.OverloadError` carrying a
      retry-after estimate).  ``max_inflight_entities``: the hard cap
      on concurrently in-flight entities (required > 0 once admission
      is enabled).  ``admission_queue_cap``: bound on pending-lane
      entities; overflowing it sheds even under ``"queue"``.
      ``submit(..., priority=)`` orders the pending lane.
      **Admission v2** (both require admission enabled; both off by
      default): ``admission_tenants``: ``{tenant: weight}`` weighted
      fair shares of the admission budget — ``submit(..., tenant=)``
      names the lane, unlisted tenants weigh
      ``admission_tenant_default_weight``, and the empty tenant
      (plain in-process submits) is exempt.
      ``admission_cost_aware`` + ``admission_cost_cap_s``: charge each
      entity its estimated work-seconds (ops x the cost tracker's
      calibrated mean) against a work-seconds budget instead of
      counting raw entities.

    **Fault tolerance** (off by default; every default reproduces
    today's behavior bit-for-bit) —
      ``max_retries``: attempts per remote request (first included).
      ``retry_backoff_base_s`` / ``retry_backoff_max_s``: bounded
      exponential backoff with full jitter between retries (base 0.0 =
      instant resubmit, the old behavior); a retry always targets a
      *different* live server than the one that just failed.
      ``heartbeat_timeout_s``: remote servers beat a
      :class:`~repro.distributed.fault.HeartbeatMonitor`; a silent
      server (died without an error reply) is declared dead and its
      in-flight work requeued to live peers.  ``fallback``: ``"none"``
      | ``"native"`` — on a transient final-attempt failure, re-route
      the failing op to the native backend instead of failing the
      entity (each op falls back at most once).  ``breaker_enabled``
      (+ ``breaker_failure_threshold`` / ``breaker_open_s`` /
      ``breaker_probes``, requires ``dispatch="cost"``): per-backend
      circuit breakers whose error-rate EWMA feeds the router as a
      health penalty; an OPEN backend is unroutable until its
      half-open probes succeed.  ``fault_injector``: a seeded
      :class:`~repro.distributed.fault.FaultInjector` deterministically
      injecting error/crash/latency/die/hang faults into remote
      servers and offload backends (tests and resilience benchmarks;
      ``None`` disables injection entirely).
      ``submit(..., timeout_s=)`` bounds the retry deadline budget.

    Public surface: :meth:`submit` / :meth:`execute` for queries,
    :meth:`add_entity` for ingest, :meth:`scale_remote` for elasticity,
    and the introspection quartet :meth:`utilization` /
    :meth:`cache_stats` / :meth:`dispatch_stats` /
    :meth:`admission_stats`, plus the deterministic coalescing controls
    :meth:`flush_coalesced` / :meth:`pending_coalesced`.  Always call
    :meth:`shutdown` (all loop, pool, and backend threads are joined;
    afterwards ``submit`` raises)."""

    def __init__(self, *, num_remote_servers: int = 1,
                 transport: TransportModel | None = None,
                 fuse_native: bool = False,
                 batch_remote: int = 1,
                 dispatch_policy: str = "round_robin",
                 num_native_workers: int | None = None,
                 # analysis: ok(knob-inert) — deliberate: FIFO starvation is a known seed defect; fairness-off is the opt-out
                 fair_scheduling: bool = True,
                 cache_capacity: int = 0,
                 cache_capacity_bytes: int = 256 << 20,
                 coalesce_window_ms: float = 0.0,
                 coalesce_max_batch: int = 64,
                 dispatch: str = "static",
                 cost_overrides: dict | None = None,
                 batcher_group_size: int = 8,
                 batcher_max_wait_ms: float = 2.0,
                 device_backend: bool | str = False,
                 device_batch_size: int = 8,
                 device_max_wait_ms: float = 2.0,
                 device_fuse_segments: bool | None = None,
                 num_device_workers: int | None = None,
                 admission: str = "none",
                 max_inflight_entities: int = 0,
                 admission_queue_cap: int = 1024,
                 admission_tenants: dict | None = None,
                 admission_tenant_default_weight: float = 1.0,
                 admission_cost_aware: bool = False,
                 admission_cost_cap_s: float = 0.0,
                 max_retries: int = 3,
                 retry_backoff_base_s: float = 0.0,
                 retry_backoff_max_s: float = 1.0,
                 heartbeat_timeout_s: float = 0.0,
                 fallback: str = "none",
                 breaker_enabled: bool = False,
                 breaker_failure_threshold: float | None = None,
                 breaker_open_s: float | None = None,
                 breaker_probes: int | None = None,
                 fault_injector=None):
        if admission not in ("none", "queue", "shed"):
            raise ValueError(
                f"admission must be 'none' (accept everything, the "
                f"paper-faithful default), 'queue' (park overflow in a "
                f"priority lane) or 'shed' (reject with OverloadError), "
                f"got {admission!r}")
        if admission == "none" and max_inflight_entities:
            # a cap no policy enforces would be silently inert — same
            # failure mode as a stray cost override
            raise ValueError(
                "max_inflight_entities requires admission='queue' or "
                "'shed' (admission='none' never consults the cap)")
        if admission == "none":
            # admission-v2 knobs parameterize the controller only —
            # with no controller they would be silently inert
            for val, name, default in (
                    (admission_tenants, "admission_tenants", None),
                    (admission_tenant_default_weight,
                     "admission_tenant_default_weight", 1.0),
                    (admission_cost_aware, "admission_cost_aware", False),
                    (admission_cost_cap_s, "admission_cost_cap_s", 0.0)):
                if val != default:
                    raise ValueError(
                        f"{name} requires admission='queue' or 'shed' "
                        f"(admission='none' builds no controller to "
                        f"consult it)")
        # built pre-thread: a malformed admission knob (cap <= 0, bad
        # queue cap, malformed tenant table, cost knobs half-set) must
        # raise before any pool/loop thread exists
        self.admission_ctl = (
            AdmissionController(
                max_inflight=max_inflight_entities,
                policy=admission,
                queue_cap=admission_queue_cap,
                tenant_weights=admission_tenants,
                tenant_default_weight=admission_tenant_default_weight,
                cost_aware=admission_cost_aware,
                cost_cap_s=admission_cost_cap_s)
            if admission != "none" else None)
        self.admission = admission
        if dispatch not in ("static", "cost", "native"):
            raise ValueError(
                f"dispatch must be 'static' (paper-faithful placement), "
                f"'cost' (cost-model router) or 'native' (all-native "
                f"baseline), got {dispatch!r}")
        if device_backend and dispatch != "cost":
            # a device backend no router can place work on would be
            # silently inert — same failure mode as a stray override
            raise ValueError(
                "device_backend requires dispatch='cost' (only the "
                "cost-model router can place segments on the device)")
        if not device_backend:
            # knobs that only parameterize the device backend must not
            # pass silently on an engine that never builds one (the
            # stray-override failure mode)
            if device_fuse_segments is not None:
                raise ValueError(
                    "device_fuse_segments requires device_backend "
                    "(there is no device segment to fuse without it)")
            if num_device_workers is not None:
                raise ValueError(
                    "num_device_workers requires device_backend "
                    "(there are no device workers without it)")
        elif num_device_workers is not None and num_device_workers < 1:
            raise ValueError(
                f"num_device_workers must be >= 1, got "
                f"{num_device_workers!r}")
        device_pool = None
        if device_backend:
            # resolve the device set HERE, before any pool/loop thread
            # exists: jax raises on a platform string this host does not
            # have, and that failure must not leak running threads
            import jax
            if isinstance(device_backend, str) and device_backend != "auto":
                device_pool = jax.devices(device_backend)
            else:
                device_pool = jax.devices()
        if dispatch == "static":
            if cost_overrides:
                # a forced regime with no router would be silently inert
                # — the caller almost certainly forgot dispatch="cost"
                raise ValueError(
                    "cost_overrides requires dispatch='cost' or 'native' "
                    "(dispatch='static' never consults a cost model)")
        else:
            # shape-check the knob BEFORE any pool/loop/batcher/device
            # thread exists: a malformed override must not leak running
            # threads (validated under "native" too, where it is merely
            # unused, so a typo'd regime never passes silently).
            # "device" is only a valid override target when the device
            # backend is actually enabled: a pinned device regime on an
            # engine with no device backend would either be silently
            # inert (dispatch="native") or fail inside BackendRouter
            # after threads exist (dispatch="cost") — both fail here
            # instead.
            known = ("native", "remote", "batcher") \
                + (("device",) if device_backend else ())
            validate_overrides(cost_overrides, known=known)
        # fault-tolerance knobs, validated BEFORE any thread exists
        # (same discipline as admission/dispatch above)
        if fallback not in ("none", "native"):
            raise ValueError(
                f"fallback must be 'none' (a final-attempt failure fails "
                f"the entity, the paper-faithful default) or 'native' "
                f"(re-route the failing op to the native backend), got "
                f"{fallback!r}")
        if max_retries < 1:
            raise ValueError(
                f"max_retries must be >= 1 (the first attempt counts), "
                f"got {max_retries!r}")
        if breaker_enabled and dispatch != "cost":
            # a breaker no router consults would be silently inert —
            # health only changes behavior through the cost-model DP
            raise ValueError(
                "breaker_enabled requires dispatch='cost' (only the "
                "cost-model router consults backend health)")
        if not breaker_enabled:
            for val, name in ((breaker_failure_threshold,
                               "breaker_failure_threshold"),
                              (breaker_open_s, "breaker_open_s"),
                              (breaker_probes, "breaker_probes")):
                if val is not None:
                    raise ValueError(
                        f"{name} requires breaker_enabled (there is no "
                        f"circuit breaker to parameterize without it)")
        self.health = None
        self.fallback = fallback
        if breaker_enabled:
            names = ["native", "remote", "batcher"]
            if device_backend:
                names.append("device")
            bk = {}
            if breaker_failure_threshold is not None:
                bk["failure_threshold"] = breaker_failure_threshold
            if breaker_open_s is not None:
                bk["open_s"] = breaker_open_s
            if breaker_probes is not None:
                bk["half_open_probes"] = breaker_probes
            self.health = HealthRegistry(names, **bk)
        # gates the fault-tolerance stats blocks in dispatch_stats(): a
        # default engine's dict stays byte-identical to the baseline
        self._ft_visible = (fault_injector is not None
                            or heartbeat_timeout_s > 0.0
                            or retry_backoff_base_s > 0.0
                            or breaker_enabled or fallback != "none")
        self.meta = MetadataStore()
        self.store = BlobStore()
        self.erd = ERD()
        self.pool = RemoteServerPool(
            num_remote_servers, transport,
            policy=dispatch_policy,
            max_retries=max_retries,
            retry_backoff_base_s=retry_backoff_base_s,
            retry_backoff_max_s=retry_backoff_max_s,
            heartbeat_timeout_s=heartbeat_timeout_s,
            fault_injector=fault_injector)
        # hot-path perf subsystems, both paper-faithful OFF by default:
        # cache_capacity > 0 enables the (eid, pipeline-signature) result
        # cache; coalesce_window_ms > 0 enables cross-session remote
        # request coalescing (one batched request per op signature per
        # window, amortized via TransportModel.cost_batch)
        self.result_cache = (ResultCache(cache_capacity,
                                         cache_capacity_bytes)
                             if cache_capacity > 0 else None)
        self._sessions: dict[str, QuerySession] = {}
        self._session_lock = threading.Lock()
        # None -> cpu-bounded pool; 1 -> the paper-faithful single Thread_2
        self.num_native_workers = (num_native_workers
                                   if num_native_workers is not None
                                   else _default_native_workers())
        # multi-backend dispatch ("static", the default, builds none of
        # this and stays byte-identical to the paper engine): a per-op
        # cost tracker calibrated by the native workers, the GroupBatcher
        # promoted to a backend, and a router the planner consults at
        # expand time (repro.query.dispatch)
        self.dispatch = dispatch
        self.cost_tracker = None
        self.router = None
        self.batcher_backend = None
        self.device_backend = None
        if dispatch != "static":
            self.cost_tracker = OpCostTracker()
            if dispatch == "cost":
                # deferred: serving.batcher pulls in the model stack,
                # which a non-batcher engine never needs
                from repro.serving.batcher import UDFBatcherBackend
                self.batcher_backend = UDFBatcherBackend(
                    group_size=batcher_group_size,
                    max_wait_s=batcher_max_wait_ms / 1000.0,
                    tracker=self.cost_tracker)
                if device_backend:
                    # deferred for the same reason: the device executor
                    # pulls in jax device plumbing a CPU-only engine
                    # never needs.  device_backend=True/"auto" targets
                    # jax's default device; a platform string ("cpu",
                    # "gpu", "tpu") pins one (resolved above, pre-thread).
                    # Fusion defaults ON; one worker per visible device
                    # unless num_device_workers pins the count (a single
                    # worker stays a plain DeviceBackend — no wrapper
                    # indirection on the common path).
                    from repro.query.device_backend import (
                        DeviceBackend, MultiDeviceBackend)
                    fuse = (device_fuse_segments
                            if device_fuse_segments is not None else True)
                    count = (num_device_workers
                             if num_device_workers is not None
                             else len(device_pool))
                    workers = [
                        DeviceBackend(
                            batch_size=device_batch_size,
                            max_wait_s=device_max_wait_ms / 1000.0,
                            tracker=self.cost_tracker,
                            device=device_pool[i % len(device_pool)],
                            fuse_segments=fuse)
                        for i in range(count)]
                    self.device_backend = (
                        workers[0] if count == 1
                        else MultiDeviceBackend(workers))
                if fault_injector is not None:
                    # offload backends consult the injector per group
                    # run (site "backend:<name>"); remote servers got
                    # theirs via the pool above
                    self.batcher_backend.fault_injector = fault_injector
                    if self.device_backend is not None:
                        self.device_backend.fault_injector = \
                            fault_injector
        self.loop = EventLoop(self.pool, self.erd,
                              fuse_native=fuse_native,
                              batch_remote=batch_remote,
                              num_native_workers=self.num_native_workers,
                              fair_scheduling=fair_scheduling,
                              on_entity_done=self._entity_done,
                              is_cancelled=self._is_cancelled,
                              coalesce_window_s=coalesce_window_ms / 1000.0,
                              coalesce_max_batch=coalesce_max_batch,
                              result_cache=self.result_cache,
                              batcher_backend=self.batcher_backend,
                              device_backend=self.device_backend,
                              cost_tracker=self.cost_tracker,
                              health=self.health,
                              fallback_native=fallback == "native")
        if dispatch == "native":
            self.router = StaticRouter("native")
        elif dispatch == "cost":
            self.batcher_backend.bind(self.loop.queue2, self._is_cancelled)
            backends = [NativeBackend(self.loop, self.cost_tracker),
                        RemoteBackend(self.pool, self.cost_tracker),
                        self.batcher_backend]
            if self.device_backend is not None:
                self.device_backend.bind(self.loop.queue2,
                                         self._is_cancelled)
                backends.append(self.device_backend)
            self.router = BackendRouter(
                backends,
                overrides=cost_overrides,
                tracker=self.cost_tracker,
                health=self.health)
        self.planner = QueryPlanner(self.meta, self.store,
                                    result_cache=self.result_cache,
                                    router=self.router)
        if self.admission_ctl is not None:
            self.admission_ctl.bind(
                loop=self.loop, pool=self.pool, launch=self._launch_now,
                offload_backends=(self.batcher_backend, self.device_backend),
                tracker=self.cost_tracker)
        self._qid = itertools.count()
        self._shut = False

    # ------------------------------------------------------------ ingest
    def add_entity(self, kind: str, data, properties: dict, *,
                   eid: str | None = None) -> str:
        return self.planner.ingest(kind, data, properties, eid=eid)

    # ------------------------------------------------------------- query
    def submit(self, query: list[dict] | dict, *,
               on_entity: Optional[Callable[[Entity], None]] = None,
               cache: bool = True, priority: int = 0,
               timeout_s: Optional[float] = None,
               tenant: str = "") -> QueryFuture:
        """Submit a VDMS JSON query; returns immediately with a
        :class:`QueryFuture`.

        ``query`` is a list of command dicts (``FindImage`` /
        ``FindVideo`` / ``AddImage`` / ``AddVideo`` — see
        ``repro.query.language``).  Submission cost is O(fan-out)
        pointer work only: the query is parsed, compiled to a phased
        plan, and its first phase launched onto the event loop without
        waiting for any operation to execute.

        The returned future supports ``result(timeout)``, ``done()``,
        ``cancel()``, ``exception()``, and ``add_done_callback(fn)``.
        ``on_entity(entity)`` additionally streams each entity as it
        completes its pipeline — called from event-loop threads, so the
        callback must be quick and thread-safe.

        ``cache=False`` makes this query bypass the result cache (no
        reads, no writes); it is a no-op when the engine was built
        without a cache (``cache_capacity=0``, the default).

        ``priority`` orders the admission controller's pending lane
        (higher first, FIFO within a priority); ignored (and harmless)
        when ``admission="none"``.  Under ``admission="shed"`` a query
        whose first phase does not fit under ``max_inflight_entities``
        raises :class:`~repro.query.admission.OverloadError` from this
        call — fail fast, with ``retry_after_s`` attached — and nothing
        of it is launched.

        ``timeout_s`` sets the query's retry deadline budget: remote
        retries (and their backoff sleeps) never outlive it, so a
        retrying request cannot keep burning server capacity after the
        client's own ``result(timeout)`` would have given up.
        ``execute(query, timeout)`` wires its timeout through here.

        ``tenant`` names the admission-v2 quota lane the query charges
        (``admission_tenants`` weighted fair shares); the default empty
        tenant is exempt from quotas, and the knob is inert unless the
        engine was built with a tenant table."""
        if self._shut:
            raise RuntimeError("engine is shut down")
        cmds = parse_query(query)
        plan = self.planner.compile(cmds)
        qid = str(next(self._qid))
        deadline = (time.monotonic() + timeout_s
                    if timeout_s is not None else None)
        session = QuerySession(qid, plan, self, on_entity=on_entity,
                               use_cache=cache, priority=priority,
                               deadline=deadline, tenant=tenant)
        fut = QueryFuture(session)     # built before launch: the return
        with self._session_lock:       # after start() is a single bytecode
            if self._shut:
                # re-checked under the lock shutdown() snapshots with: a
                # session registered here is in that snapshot and gets
                # cancelled; one refused here never launches — either
                # way the future resolves, never a post-shutdown hang
                raise RuntimeError("engine is shut down")
            self._sessions[qid] = session
        session.start()
        if self.admission_ctl is not None:
            # shed fails FAST: an OverloadError raised while start() ran
            # phase 0 on this thread surfaces here as the submit()
            # exception (the session is already discarded and its future
            # resolved — callers holding neither see a hang)
            exc = session.sync_overload()
            if exc is not None:
                raise exc
        return fut

    def execute(self, query: list[dict] | dict, timeout: float | None = None,
                *, cache: bool = True) -> dict:
        """Run a VDMS JSON query; returns {"entities": {eid: array},
        "stats": {...}}.  Blocks until the pipeline drains (the client-
        facing call is synchronous, like VDMS; internally it is
        ``submit().result()``).  ``timeout`` now bounds the *whole query*
        (the old loop applied it per command) and on expiry the query is
        *cancelled* — its queued and in-flight entities are dropped,
        nothing leaks — where the old loop raised and orphaned them."""
        fut = self.submit(query, cache=cache, timeout_s=timeout)
        try:
            return fut.result(timeout)
        except TimeoutError:
            fut.cancel()
            raise

    # --------------------------------------------------- session plumbing
    def _expand(self, cplan: CommandPlan, qid: str,
                use_cache: bool = True) -> list[Entity]:
        return self.planner.expand(cplan, qid, use_cache)

    def _admission_precheck(self, cplans, *, qid: str, first_phase: bool,
                            use_cache: bool = True, tenant: str = ""):
        """Pre-expand overload gate, deciding before any expansion work
        happens.  It runs in exactly two situations:

        - an **Add barrier phase** (Add is always the sole member of
          its phase, so the estimate is O(1)) — the controller
          atomically decides AND **reserves** the capacity under both
          policies, because the admission decision (shed, or queue-cap
          overflow) must come before the barrier's ingest side effect,
          and a check without a claim would let two queries racing the
          same last slot both pass, both ingest, then have one rejected
          post-ingest;
        - a **Find phase when the controller is saturated** — but only
          when the result cache cannot serve it (cache off, or the
          query opted out): entities the cache resolves as instant full
          hits consume no capacity and never reach :meth:`_launch`, so
          shedding on the raw match count would reject free queries.
          Find expansion has no side effects, so this stays an
          advisory check (no reservation).

        No-op on the uncontended path; the post-expand check in
        :meth:`_launch` (which sees only the entities that actually
        need capacity) stays the authority."""
        ctl = self.admission_ctl
        if ctl is None:
            return
        # cost-aware admission charges per estimated op count: the
        # widest command of the phase bounds the per-entity charge
        n_ops = max((len(cp.command.operations) for cp in cplans),
                    default=1)
        is_add_phase = any(cp.command.verb == "add" for cp in cplans)
        if is_add_phase:
            ctl.reserve(qid, self.planner.estimate_fanout(cplans),
                        first_phase=first_phase, tenant=tenant,
                        n_ops=n_ops)
            return
        if not ctl.saturated():
            return
        if self.result_cache is not None and use_cache:
            return
        ctl.precheck(self.planner.estimate_fanout(cplans),
                     first_phase=first_phase, tenant=tenant, n_ops=n_ops)

    def _launch(self, ents: list[Entity], *, priority: int = 0,
                first_phase: bool = True, tenant: str = ""):
        """Launch one phase's entities, gated by admission control when
        enabled: the controller returns the subset that fits under
        ``max_inflight_entities`` now, parks the rest in its pending
        lane, or raises :class:`OverloadError` (shedding) — in which
        case nothing was launched or queued."""
        ctl = self.admission_ctl
        if ctl is not None:
            qid = ents[0].query_id if ents else ""
            n_ops = max((len(e.ops) for e in ents), default=1)
            ents = ctl.admit_phase(qid, ents, priority,
                                   first_phase=first_phase,
                                   tenant=tenant, n_ops=n_ops)
            if qid and self._is_cancelled(qid):
                # cancel raced the admission: if its drop_query ran
                # BEFORE admit_phase re-entered this query in the
                # ledger, the slots just taken would leak forever
                # (workers skip cancelled entities without a completion
                # callback).  Release them; keep only other queries'
                # drained pending entities.
                ents = [e for e in ents if e.query_id != qid]
                ents += ctl.drop_query(qid)
        self._launch_now(ents)

    def _launch_now(self, ents: list[Entity]):
        # Pointers land on Queue_1 as one batch: workers wake only after
        # the whole phase is queued, so submit() stays milliseconds-fast
        # instead of GIL-starving behind already-running native work.
        for e in ents:
            self.erd.update(e, "enqueued")
        if ents:
            self.loop.enqueue_many(ents)

    def _store_result(self, ent: Entity):
        self.store.put(ent.eid, np.asarray(ent.data))
        if self.result_cache is not None:
            # blob write-back (Add with operations): cached results for
            # this eid were computed from the blob just overwritten
            self.result_cache.invalidate(ent.eid)

    def _entity_done(self, ent: Entity):
        with self._session_lock:
            session = self._sessions.get(ent.query_id)
        try:
            if session is not None:
                session.entity_done(ent)
        finally:
            if self.admission_ctl is not None and not ent.admission_released:
                # a completed entity frees an in-flight slot: drain the
                # pending lane right here on the event-loop thread that
                # delivered the completion (no polling thread needed).
                # In a finally: a raising session callback (e.g. a
                # blob-store write-back failure) must never leak the
                # slot — a few leaks would pin the ledger at the cap and
                # stall every later query.  The per-entity flag keeps
                # the release idempotent: after such a raise the worker
                # error path delivers the SAME entity here a second
                # time, which must not double-release capacity.
                ent.admission_released = True
                self._launch_now(self.admission_ctl.note_done(ent))

    def _is_cancelled(self, qid: str) -> bool:
        # hot path (checked at every op boundary by every worker): a bare
        # dict.get is GIL-atomic, so skip _session_lock here — it would
        # serialize the whole native pool on one lock
        session = self._sessions.get(qid)
        return session is None or session.is_cancelled

    def _session_finished(self, qid: str):
        with self._session_lock:
            self._sessions.pop(qid, None)

    def _discard_session(self, qid: str):
        """Cancellation/timeout cleanup: forget the session, drop its
        queued native work, its in-flight remote requests, and its
        pending/in-flight admission ledger entries (freed capacity
        immediately admits other queries' pending entities)."""
        with self._session_lock:
            self._sessions.pop(qid, None)
        self.loop.discard_query(qid)
        self.pool.drop_query(qid)
        if self.admission_ctl is not None:
            self._launch_now(self.admission_ctl.drop_query(qid))

    def active_sessions(self) -> int:
        with self._session_lock:
            return len(self._sessions)

    # -------------------------------------------------------- operations
    def scale_remote(self, n: int):
        self.pool.scale_to(n)

    def utilization(self) -> dict:
        return {
            "thread2_busy_s": self.loop.t2_meter.busy_seconds(),
            "thread3_busy_s": self.loop.t3_meter.busy_seconds(),
            "native_workers": self.num_native_workers,
            "remote_processed": sum(s.processed for s in self.pool.servers),
            "remote_dispatched": self.pool.dispatched,
            "remote_transport_busy_s": sum(s.transport_busy_s
                                           for s in self.pool.servers),
            "coalesced_batches": self.loop.coalesced_batches,
            "coalesced_entities": self.loop.coalesced_entities,
            "retried": self.pool.retried,
            "reissued": self.pool.reissued,
            "duplicates_dropped": self.pool.duplicates_dropped,
            "cancelled_dropped": self.pool.cancelled_dropped,
        }

    def cache_stats(self) -> dict:
        """Engine-lifetime result-cache counters (empty dict when the
        cache is off): ``size`` / ``bytes`` and their capacities,
        ``hits`` / ``prefix_hits`` / ``misses`` / ``hit_rate``, and the
        write-side ledger (``puts``, ``stale_puts``, ``oversize_puts``,
        ``evictions``, ``invalidations``).  Per-query hit counts ride
        on each response's ``stats`` instead (``cache_full_hits`` /
        ``cache_prefix_hits``)."""
        return (self.result_cache.stats()
                if self.result_cache is not None else {})

    def dispatch_stats(self) -> dict:
        """Multi-backend router counters: ``placements`` (ops placed
        per backend), ``handoffs`` / ``segments`` / ``chains_routed``,
        live ``queue_depths``, plus per-backend accounting blocks —
        ``batcher`` (groups/entities run, errors, cancelled drops) and
        ``device`` (groups/entities/ops run, ``fused_segments``, jit
        ``compiles`` + bounded-cache ``jit_entries``/``jit_evictions``,
        calibration state, ``h2d_bytes``/``d2h_bytes`` moved,
        ``padding_waste_frac``, and — with ``num_device_workers > 1``
        — a ``per_device`` breakdown) when those backends exist.  ``{"mode": "static"}`` alone when the router is off
        (not to be confused with ``dispatch_policy``, the remote pool's
        round-robin/least-loaded server picker)."""
        out: dict = {"mode": self.dispatch}
        if self.router is not None:
            out.update(self.router.stats())
        if self.batcher_backend is not None:
            out["batcher"] = self.batcher_backend.stats()
        if self.device_backend is not None:
            out["device"] = self.device_backend.stats()
        if self.health is not None:
            out["breakers"] = self.health.stats()
        if self._ft_visible:
            # only when a fault-tolerance knob is on: a default engine's
            # dict stays byte-identical to the baseline
            out["pool"] = self.pool.health_stats()
            out["fallbacks"] = self.loop.fallbacks
        return out

    def admission_stats(self) -> dict:
        """Admission-control counters (``{"policy": "none"}`` alone when
        admission is off): the live ``inflight`` / ``peak_inflight`` /
        ``pending`` ledger, lifetime ``admitted`` / ``queued`` /
        ``shed`` / ``completed`` / ``dropped`` counts, the
        ``completion_rate_est`` feeding retry-after estimates, and the
        ``load`` score component snapshot (see
        :meth:`repro.query.admission.AdmissionController.load_score`)."""
        if self.admission_ctl is None:
            return {"policy": "none"}
        return self.admission_ctl.stats()

    def pending_coalesced(self) -> int:
        """Entities buffered in open coalescing groups right now — the
        deterministic signal to poll instead of sleeping out the
        wall-clock window (always 0 when coalescing is off)."""
        return self.loop.pending_coalesced()

    def flush_coalesced(self):
        """Force-dispatch all open coalescing groups now, regardless of
        their window deadlines — the deterministic alternative to
        waiting out ``coalesce_window_ms`` (tests, graceful drains).
        Asynchronous: the flush is processed by Thread_3; a no-op when
        coalescing is off."""
        self.loop.flush_coalesced()

    def shutdown(self):
        """Deterministic teardown, safe with sessions still in flight:
        new ``submit``\\ s are refused first, every live session is
        cancelled (blocked ``result()`` callers wake with
        ``CancelledError``), pending admissions are dropped, the
        offload backends drain behind their poison pills (late routed
        work fails loudly instead of vanishing), and every loop, pool,
        and backend thread is joined.  Idempotent."""
        with self._session_lock:
            # setting the flag under the registration lock makes the
            # snapshot below complete: every submit() that got past the
            # flag is in it, every later one raises
            self._shut = True
            live = list(self._sessions.values())
        if self.admission_ctl is not None:
            # refuse new admissions before cancelling sessions, so a
            # cancel-triggered drain cannot relaunch pending work
            self.admission_ctl.shutdown()
        for s in live:            # wake any blocked result() callers
            s.cancel()
        if self.batcher_backend is not None:
            self.batcher_backend.shutdown()
        if self.device_backend is not None:
            self.device_backend.shutdown()
        self.loop.shutdown()
        self.pool.shutdown()
