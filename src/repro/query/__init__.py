"""Query layer: VDMS-style JSON language, metadata store, the per-query
planner that compiles commands into phased execution plans, and the
cost-model multi-backend dispatch router the planner consults."""
from repro.query.dispatch import (Backend, BackendRouter,  # noqa: F401
                                  NativeBackend, OpCostTracker,
                                  RemoteBackend, StaticRouter)
from repro.query.language import Command, parse_query  # noqa: F401
from repro.query.metadata import MetadataStore  # noqa: F401
from repro.query.planner import CommandPlan, QueryPlan, QueryPlanner  # noqa: F401
