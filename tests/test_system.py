"""End-to-end behaviour tests for the VDMS-Async engine (the paper's
system): query execution, pipeline ordering, multi-client concurrency,
fault tolerance, and architecture-comparison invariants."""
import threading
import time

import numpy as np
import pytest

from repro.core.engine import VDMSAsyncEngine
from repro.core.entity import Entity, ERD
from repro.core.executors import FrameExecutor, PooledExecutor, SyncExecutor
from repro.core.pipeline import make_op
from repro.core.remote import RemoteServerPool, TransportModel

FAST = TransportModel(network_latency_s=0.001, service_time_s=0.002)


def _mk_engine(**kw):
    kw.setdefault("num_remote_servers", 2)
    kw.setdefault("transport", FAST)
    return VDMSAsyncEngine(**kw)


def _add_images(eng, n=10, size=32):
    rng = np.random.default_rng(0)
    ids = []
    for i in range(n):
        img = rng.uniform(0, 1, (size, size, 3)).astype(np.float32)
        ids.append(eng.add_entity("image", img, {
            "category": "lfw", "name": f"p{i}", "age": 20 + i}))
    return ids


PIPE = [
    {"type": "resize", "width": 24, "height": 24},
    {"type": "remote", "url": "http://s/box", "options": {"id": "facedetect_box"}},
    {"type": "threshold", "value": 0.4},
]


def test_query_returns_all_matching_entities():
    eng = _mk_engine()
    try:
        _add_images(eng, 10)
        res = eng.execute([{"FindImage": {
            "constraints": {"category": ["==", "lfw"]},
            "operations": PIPE}}], timeout=60)
        assert res["stats"]["matched"] == 10
        assert res["stats"]["failed"] == 0
        assert len(res["entities"]) == 10
        for arr in res["entities"].values():
            assert np.asarray(arr).shape == (24, 24, 3)
            # threshold output is binary
            vals = np.unique(np.asarray(arr).round(3))
            assert set(vals).issubset({0.0, 1.0})
    finally:
        eng.shutdown()


def test_constraint_filtering():
    eng = _mk_engine()
    try:
        _add_images(eng, 10)
        res = eng.execute([{"FindImage": {
            "constraints": {"age": [">=", 25, "<", 28]},
            "operations": [{"type": "grayscale"}]}}], timeout=30)
        assert res["stats"]["matched"] == 3  # ages 25,26,27
    finally:
        eng.shutdown()


def test_pipeline_order_preserved():
    """resize->crop != crop->resize; engine must respect user order."""
    eng = _mk_engine()
    try:
        rng = np.random.default_rng(1)
        img = rng.uniform(0, 1, (40, 40, 3)).astype(np.float32)
        eng.add_entity("image", img, {"category": "x"})
        r1 = eng.execute([{"FindImage": {
            "constraints": {"category": ["==", "x"]},
            "operations": [{"type": "resize", "width": 20, "height": 20},
                           {"type": "crop", "x": 0, "y": 0,
                            "width": 10, "height": 10}]}}], timeout=30)
        (arr1,) = list(r1["entities"].values())
        assert np.asarray(arr1).shape == (10, 10, 3)
    finally:
        eng.shutdown()


def test_multi_client_concurrent_queries():
    eng = _mk_engine(num_remote_servers=4)
    try:
        _add_images(eng, 12)
        results = {}

        def client(cid):
            results[cid] = eng.execute([{"FindImage": {
                "constraints": {"category": ["==", "lfw"]},
                "operations": PIPE}}], timeout=120)

        threads = [threading.Thread(target=client, args=(i,)) for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert len(results) == 4
        for r in results.values():
            assert r["stats"]["matched"] == 12
            assert r["stats"]["failed"] == 0
    finally:
        eng.shutdown()


def test_failure_retry_and_elastic_scale():
    eng = _mk_engine(num_remote_servers=3)
    try:
        _add_images(eng, 8)

        def killer():
            time.sleep(0.02)
            eng.pool.kill_server(0)

        threading.Thread(target=killer).start()
        res = eng.execute([{"FindImage": {
            "constraints": {"category": ["==", "lfw"]},
            "operations": PIPE}}], timeout=120)
        assert res["stats"]["failed"] == 0
        assert eng.pool.live_count() == 2
        eng.scale_remote(5)
        assert eng.pool.live_count() == 5
        res2 = eng.execute([{"FindImage": {
            "constraints": {"category": ["==", "lfw"]},
            "operations": PIPE}}], timeout=120)
        assert res2["stats"]["failed"] == 0
    finally:
        eng.shutdown()


def test_async_matches_sync_results():
    """The event-driven engine must produce byte-identical results to the
    synchronous VDMS baseline."""
    pool = RemoteServerPool(2, FAST)
    rng = np.random.default_rng(2)
    imgs = [rng.uniform(0, 1, (32, 32, 3)).astype(np.float32) for _ in range(6)]
    ops = [make_op("resize", {"width": 24, "height": 24}),
           make_op("facedetect_box", {}, where="remote"),
           make_op("grayscale")]

    sync_ents = [Entity(str(i), "image", img.copy(), ops=list(ops))
                 for i, img in enumerate(imgs)]
    SyncExecutor(pool).run(sync_ents)

    eng = _mk_engine(num_remote_servers=2)
    try:
        for i, img in enumerate(imgs):
            eng.add_entity("image", img, {"category": "c", "idx": i})
        res = eng.execute([{"FindImage": {
            "constraints": {"category": ["==", "c"]},
            "operations": [
                {"type": "resize", "width": 24, "height": 24},
                {"type": "remote", "url": "u", "options": {"id": "facedetect_box"}},
                {"type": "grayscale"}]}}], timeout=60)
        by_idx = {eng.meta.get(eid)["idx"]: arr
                  for eid, arr in res["entities"].items()}
        for i, ent in enumerate(sync_ents):
            np.testing.assert_allclose(np.asarray(by_idx[i]),
                                       np.asarray(ent.data), atol=1e-6)
    finally:
        eng.shutdown()
        pool.shutdown()


def test_fused_pipeline_matches_unfused():
    eng_f = _mk_engine(fuse_native=True)
    eng_u = _mk_engine(fuse_native=False)
    try:
        rng = np.random.default_rng(3)
        img = rng.uniform(0, 1, (32, 32, 3)).astype(np.float32)
        q = [{"FindImage": {"constraints": {"category": ["==", "z"]},
                            "operations": [
                                {"type": "resize", "width": 16, "height": 16},
                                {"type": "grayscale"},
                                {"type": "threshold", "value": 0.5}]}}]
        eng_f.add_entity("image", img, {"category": "z"})
        eng_u.add_entity("image", img, {"category": "z"})
        (a,) = list(eng_f.execute(q, timeout=30)["entities"].values())
        (b,) = list(eng_u.execute(q, timeout=30)["entities"].values())
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    finally:
        eng_f.shutdown()
        eng_u.shutdown()


def test_video_pipeline_executors_agree():
    pool = RemoteServerPool(2, FAST)
    rng = np.random.default_rng(4)
    vid = rng.uniform(0, 1, (4, 24, 24, 3)).astype(np.float32)
    ops = [make_op("grayscale"), make_op("threshold", {"value": 0.5})]
    e1 = Entity("v1", "video", vid.copy(), ops=list(ops))
    e2 = Entity("v2", "video", vid.copy(), ops=list(ops))
    SyncExecutor(pool).run([e1])
    FrameExecutor(pool, workers=2).run([e2])
    np.testing.assert_allclose(np.asarray(e1.data), np.asarray(e2.data),
                               atol=1e-6)
    pool.shutdown()


def test_add_image_with_operations():
    eng = _mk_engine()
    try:
        rng = np.random.default_rng(5)
        img = rng.uniform(0, 1, (30, 30, 3)).astype(np.float32)
        res = eng.execute([{"AddImage": {
            "properties": {"category": "new"},
            "data": img,
            "operations": [{"type": "resize", "width": 10, "height": 10}]}}],
            timeout=30)
        (arr,) = list(res["entities"].values())
        assert np.asarray(arr).shape == (10, 10, 3)
        # stored entity is the processed one
        found = eng.execute([{"FindImage": {
            "constraints": {"category": ["==", "new"]}, "operations": []}}],
            timeout=30)
        (arr2,) = list(found["entities"].values())
        assert np.asarray(arr2).shape == (10, 10, 3)
    finally:
        eng.shutdown()
