"""qwen3-moe-235b-a22b [moe] — 128 experts, top-8, qk_norm.

94L d_model=4096 64H (GQA kv=4) expert d_ff=1536 vocab=151936.
[hf:Qwen/Qwen3-30B-A3B; hf]
"""
from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    source="hf:Qwen/Qwen3-30B-A3B; hf",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    num_experts=128,
    num_experts_per_tok=8,
    qk_norm=True,
    rope_theta=1_000_000.0,
    attention="full",
    # hillclimbed (EXPERIMENTS.md section Perf): ZeRO-3 dense weights + EP on
    # the TP axis with ZeRO-sharded expert storage — collective term 9x down
    train_sharding_overrides={"embed": "data", "experts": "model",
                              "expert_ff": "data"},
    prefill_sharding_overrides={"experts": "model", "expert_ff": "data"},
)

REDUCED = FULL.replace(
    name="qwen3-moe-235b-a22b-reduced",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=64,
    vocab_size=512,
    num_experts=8,
    num_experts_per_tok=2,
    moe_capacity_factor=4.0,  # no-drop in reduced tests
    vocab_pad_multiple=64,
)

register(FULL, REDUCED)
