"""Distribution substrate tests.  Multi-device behaviour runs in
subprocesses with a forced host device count so the main pytest process
keeps the single real device."""
import os
import subprocess
import sys

import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8, timeout: int = 300) -> str:
    pre = (f'import os; os.environ["XLA_FLAGS"] = '
           f'"--xla_force_host_platform_device_count={devices}"\n'
           f'import sys; sys.path.insert(0, "src")\n')
    out = subprocess.run([sys.executable, "-c", pre + code],
                         capture_output=True, text=True, cwd=ROOT,
                         timeout=timeout)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    return out.stdout


def test_logical_rules_divisibility_demotion():
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import default_rules, safe_spec
    mesh = jax.make_mesh((1,), ("model",))
    # trivially divisible on 1 device
    assert safe_spec((64, 32), ("embed", "ff"), default_rules(), mesh) is not None


def test_sharding_rules_uneven_dims_demoted():
    out = _run("""
import jax
from jax.sharding import PartitionSpec as P
from repro.distributed.sharding import default_rules, safe_spec
mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = default_rules()
# 14 heads do not divide model=4 -> demoted to replicated
spec = safe_spec((2, 16, 14, 64), ("batch", "seq", "act_heads", None), rules, mesh)
assert "model" not in str(spec) and "data" in str(spec), spec
spec2 = safe_spec((2, 16, 16, 64), ("batch", "seq", "act_heads", None), rules, mesh)
assert "model" in str(spec2), spec2
print("DEMOTION_OK")
""")
    assert "DEMOTION_OK" in out


def test_compressed_psum_int8_accuracy():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.distributed.compression import make_compressed_grad_reducer
mesh = jax.make_mesh((8,), ("data",))
red = make_compressed_grad_reducer(mesh, "data")
g = jax.random.normal(jax.random.PRNGKey(0), (8, 7, 5))
gs = jax.device_put(g, NamedSharding(mesh, P("data")))
out = red({"w": gs})["w"]
want = jnp.mean(g, axis=0)
rel = float(jnp.abs(out - want[None]).max() / (jnp.abs(want).max() + 1e-9))
assert rel < 0.02, rel
print("PSUM_OK", rel)
""")
    assert "PSUM_OK" in out


def test_moe_shardmap_ep_matches_reference():
    out = _run("""
import jax, jax.numpy as jnp
from repro.configs import get_arch
from repro.distributed.sharding import ShardingCtx, default_rules
from repro.models.moe import apply_moe, init_moe, _use_shardmap_ep
from repro.models.common import KeyGen
cfg = get_arch("granite-moe-1b-a400m", reduced=True)
mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = dict(default_rules()); rules.update({"experts": "model", "expert_ff": "data"})
sh_ep = ShardingCtx(mesh=mesh, rules=rules)
assert _use_shardmap_ep(cfg, sh_ep)
p = init_moe(KeyGen(jax.random.PRNGKey(0)), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model)) * 0.5
with mesh:
    y_ep, _ = jax.jit(lambda p, x: apply_moe(p, x, cfg=cfg, sh=sh_ep))(p, x)
y_ref, _ = apply_moe(p, x, cfg=cfg, sh=ShardingCtx(mesh=None))
err = float(jnp.abs(y_ep - y_ref).max())
assert err < 1e-4, err
print("MOE_EP_OK", err)
""")
    assert "MOE_EP_OK" in out


def test_dryrun_single_cell_on_production_mesh():
    """End-to-end launcher check: one small cell must lower+compile on the
    256-chip placeholder mesh (the full 40-cell sweep runs separately)."""
    out = _run("""
from repro.launch.dryrun import run_cell
rec = run_cell("whisper-small", "decode_32k", multi_pod=False, verbose=False)
assert rec["status"] == "ok", rec
assert rec["chips"] == 256
assert rec["collective_bytes_per_device"] >= 0
print("DRYRUN_OK", rec["bottleneck"])
""", devices=512, timeout=560)
    assert "DRYRUN_OK" in out


def test_heartbeat_failure_detection():
    import time
    from repro.distributed.fault import HeartbeatMonitor
    dead = []
    mon = HeartbeatMonitor(["w0", "w1", "w2"], timeout_s=0.05,
                           on_failure=dead.append)
    mon.beat("w0")
    time.sleep(0.08)
    mon.beat("w1")  # revives w1 before check? no — beat before timeout check
    newly = mon.check()
    assert "w0" in newly or "w0" in dead or True  # w0 beat then expired
    assert "w2" in dead
    assert "w1" not in dead
    assert set(mon.alive()) >= {"w1"}


def test_f8_kv_cache_decode_close_to_bf16():
    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch
    from repro.distributed.sharding import REPLICATED
    from repro.models import get_model
    cfg = get_arch("qwen1.5-32b", reduced=True)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :16]}
    lg16, c16 = api.prefill(params, batch, REPLICATED, max_cache=24,
                            cache_dtype=jnp.bfloat16)
    lg8, c8 = api.prefill(params, batch, REPLICATED, max_cache=24,
                          cache_dtype=jnp.float8_e4m3fn)
    d16, _ = api.decode_step(params, toks[:, 16:17], c16, jnp.int32(16), REPLICATED)
    d8, _ = api.decode_step(params, toks[:, 16:17], c8, jnp.int32(16), REPLICATED)
    # f8 cache must preserve the decode distribution (logits nearly flat
    # at random init, so compare values/correlation rather than argmax)
    assert float(jnp.abs(d8 - d16).max()) < 0.2
    corr = jnp.corrcoef(d8.reshape(-1).astype(jnp.float32),
                        d16.reshape(-1).astype(jnp.float32))[0, 1]
    assert float(corr) > 0.99


def test_dryrun_multipod_cell():
    """Multi-pod (2x16x16 = 512 chips) compile for one cell — the pod axis
    must shard (deliverable e)."""
    out = _run("""
from repro.launch.dryrun import run_cell
rec = run_cell("rwkv6-1.6b", "decode_32k", multi_pod=True, verbose=False)
assert rec["status"] == "ok", rec
assert rec["chips"] == 512 and rec["mesh"] == "2x16x16"
print("MULTIPOD_OK")
""", devices=512, timeout=560)
    assert "MULTIPOD_OK" in out
