"""Backend protocol conformance.

A *registered backend* is any harvested class that (a) subclasses
``Backend`` or ``OffloadInboxMixin`` (transitively, by name through
the harvested MRO), or (b) is named ``*Backend`` — the structural
backends (``UDFBatcherBackend``, ``DeviceBackend``,
``MultiDeviceBackend``) satisfy the protocol without subclassing, so
name is the only static registration signal for them.

Checked surface (all resolved through the harvested MRO):

* the router protocol: ``can_run``, ``estimate``, ``queue_depth``
  methods and a ``name`` (class attribute or set in ``__init__``);
* ``estimate_resident`` implies ``resident_capable`` is defined;
* offload backends (``OffloadInboxMixin`` in the MRO) must call
  ``self._init_inbox()`` in ``__init__``, provide ``_run_groups``,
  and ship a worker of their own that honors the shutdown contract —
  references the ``OFFLOAD_STOP`` pill AND calls
  ``self._drain_after_stop()`` (work accepted before the close is
  executed, never dropped);
* a class that hand-rolls part of the offload surface (``submit`` /
  ``pending`` / ``shutdown``) without the mixin must provide all
  three — a partial surface means the engine's teardown path will
  call a method that does not exist.
"""
from __future__ import annotations

from repro.analysis.locks import LockAnalysis
from repro.analysis.model import Finding

ROUTER_METHODS = ("can_run", "estimate", "queue_depth")
OFFLOAD_SURFACE = ("submit", "pending", "shutdown")
EXEMPT = {"Backend", "OffloadInboxMixin"}


def _registered(la: LockAnalysis) -> list[str]:
    names = []
    for cls_name in la.class_index:
        if cls_name in EXEMPT or cls_name.startswith("_"):
            continue
        mro = {c.name for c in la.mro(cls_name)}
        if cls_name.endswith("Backend") or (mro & EXEMPT):
            names.append(cls_name)
    return sorted(names)


def _defines(la: LockAnalysis, cls_name: str, member: str,
             skip=frozenset()) -> bool:
    for cf in la.mro(cls_name):
        if cf.name in skip:
            continue
        if member in cf.methods or member in cf.class_attr_names \
                or member in cf.init_self_attrs:
            return True
    return False


def check_protocols(la: LockAnalysis) -> list[Finding]:
    out: list[Finding] = []
    for cls_name in _registered(la):
        mf, cf = la.class_index[cls_name]
        mro_names = {c.name for c in la.mro(cls_name)}

        def finding(subject: str, message: str, line: int | None = None):
            out.append(Finding(
                rule="backend-protocol", severity="error",
                path=mf.path, line=line if line is not None else cf.line,
                scope=cls_name, subject=f"{cls_name}:{subject}",
                message=message))

        for meth in ROUTER_METHODS:
            # an abstractmethod on the Backend ABC satisfies nothing for
            # the subclass, but harvested methods don't carry decorator
            # info for bases outside the tree — accept MRO presence,
            # which matches how the ABC enforces it at class-creation
            if not _defines(la, cls_name, meth):
                finding(f"missing:{meth}",
                        f"backend {cls_name} does not implement "
                        f"{meth}() (Backend protocol)")
        if not _defines(la, cls_name, "name"):
            finding("missing:name",
                    f"backend {cls_name} has no `name` (class attribute "
                    f"or set in __init__)")
        if _defines(la, cls_name, "estimate_resident",
                    skip={"Backend"}) and \
                not _defines(la, cls_name, "resident_capable"):
            finding("missing:resident_capable",
                    f"{cls_name} implements estimate_resident() but "
                    f"defines no resident_capable flag")

        if "OffloadInboxMixin" in mro_names:
            init = None
            for base in la.mro(cls_name):
                if "__init__" in base.methods:
                    init = base.methods["__init__"]
                    break
            calls_init_inbox = init is not None and any(
                s.kind == "self" and s.name == "_init_inbox"
                for s in init.calls)
            if not calls_init_inbox:
                finding("offload:init-inbox",
                        f"{cls_name}.__init__ never calls "
                        f"self._init_inbox() — inbox/gate/closed state "
                        f"is missing")
            if not _defines(la, cls_name, "_run_groups",
                            skip={"OffloadInboxMixin"}):
                finding("offload:run-groups",
                        f"{cls_name} provides no _run_groups() — the "
                        f"post-join drain has nothing to execute")
            # the worker the class ships must honor the pill + drain
            # (mixin methods don't count: they are the *callers* of the
            # contract, not the worker side)
            honors = False
            for base in la.mro(cls_name):
                if base.name == "OffloadInboxMixin":
                    continue
                for facts in base.methods.values():
                    sees_pill = "OFFLOAD_STOP" in facts.global_names
                    drains = any(s.kind == "self"
                                 and s.name == "_drain_after_stop"
                                 for s in facts.calls)
                    if sees_pill and drains:
                        honors = True
            if not honors:
                finding("offload:pill-drain",
                        f"no worker method of {cls_name} both checks the "
                        f"OFFLOAD_STOP pill and calls "
                        f"_drain_after_stop() — shutdown would hang or "
                        f"drop accepted work")
        else:
            have = [m for m in OFFLOAD_SURFACE
                    if _defines(la, cls_name, m)]
            if have and len(have) != len(OFFLOAD_SURFACE):
                missing = sorted(set(OFFLOAD_SURFACE) - set(have))
                finding("offload:partial",
                        f"{cls_name} hand-rolls {sorted(have)} without "
                        f"OffloadInboxMixin but lacks {missing} — the "
                        f"offload surface must be all-or-nothing")
    return out
