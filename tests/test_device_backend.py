"""Device-executor backend: placement only when the transfer+compile-
amortized estimate wins, forced cost regimes, micro-batch cancellation
drains, and the device-off engine's byte-identity with static dispatch."""
import threading
import time

import numpy as np
import pytest

from repro.core.engine import VDMSAsyncEngine
from repro.core.pipeline import make_op
from repro.core.remote import TransportModel
from repro.core.result_cache import op_signature
from repro.core.udf import register_device_udf, register_udf
from repro.query.device_backend import DeviceBackend, DeviceCostModel
from repro.query.dispatch import BackendRouter, Backend, OpCostTracker

FAST = TransportModel(network_latency_s=0.001, service_time_s=0.002)

# a pipeline of index-permutation + comparison ops: bit-exact under ANY
# execution strategy (eager, jit, vmap), so responses can be compared
# byte-for-byte across backends — float ops like blur/resize may differ
# in the last ulp between eager per-entity and fused batched execution
EXACT_PIPE = [
    {"type": "crop", "x": 2, "y": 2, "width": 16, "height": 16},
    {"type": "rotate", "k": 1},
    {"type": "flip", "axis": "horizontal"},
    {"type": "threshold", "value": 0.5},
]

# pin the rotate op onto the device; everything else stays native
DEVICE_PIN = {
    "rotate": {"device": 1e-9, "native": 10.0, "remote": 10.0,
               "batcher": 10.0},
}


def _mk_engine(**kw):
    kw.setdefault("num_remote_servers", 2)
    kw.setdefault("transport", FAST)
    return VDMSAsyncEngine(**kw)


def _add_images(eng, n=6, size=24, category="dev"):
    rng = np.random.default_rng(5)
    for i in range(n):
        img = rng.uniform(0, 1, (size, size, 3)).astype(np.float32)
        eng.add_entity("image", img, {"category": category, "idx": i})


def _find(category="dev", ops=EXACT_PIPE, kind="FindImage"):
    return [{kind: {"constraints": {"category": ["==", category]},
                    "operations": ops}}]


def _assert_same_entities(a: dict, b: dict):
    assert list(a["entities"]) == list(b["entities"])
    for eid in a["entities"]:
        np.testing.assert_array_equal(np.asarray(a["entities"][eid]),
                                      np.asarray(b["entities"][eid]))


# ------------------------------------------------------ knob validation
def test_device_backend_requires_cost_dispatch():
    before = threading.active_count()
    with pytest.raises(ValueError, match="device_backend"):
        _mk_engine(device_backend=True)                    # static default
    with pytest.raises(ValueError, match="device_backend"):
        _mk_engine(dispatch="native", device_backend=True)
    assert threading.active_count() == before


def test_device_override_rejected_without_device_backend():
    # pinning a device regime on an engine that never built the device
    # backend must fail fast, BEFORE any loop/batcher thread exists
    before = threading.active_count()
    with pytest.raises(ValueError, match="device"):
        _mk_engine(dispatch="cost", cost_overrides=DEVICE_PIN)
    assert threading.active_count() == before


def test_device_off_cost_engine_matches_static():
    # the device backend is opt-in: a plain cost engine neither builds
    # it nor places anything on it, and its responses stay byte-equal
    # to the paper-faithful static engine
    eng_sta = _mk_engine()
    eng_cost = _mk_engine(dispatch="cost")
    try:
        assert eng_cost.device_backend is None
        assert "device" not in eng_cost.router.placements
        _add_images(eng_sta)
        _add_images(eng_cost)
        r_sta = eng_sta.execute(_find(), timeout=60)
        r_cost = eng_cost.execute(_find(), timeout=60)
        _assert_same_entities(r_sta, r_cost)
        assert "device" not in eng_cost.dispatch_stats()
    finally:
        eng_sta.shutdown()
        eng_cost.shutdown()


# ------------------------------------------------- forced device regime
def test_forced_device_regime_routes_and_matches_static():
    # fusion is the default: once the pinned rotate enters the device,
    # residency pricing keeps flip and threshold there too (marginal
    # compute beats native + handoff), so the segment is rotate-onward —
    # 3 of the 4 ops, per entity
    eng_sta = _mk_engine()
    eng_dev = _mk_engine(dispatch="cost", device_backend=True,
                         cost_overrides=DEVICE_PIN,
                         device_max_wait_ms=50.0)
    try:
        _add_images(eng_sta)
        _add_images(eng_dev)
        r_sta = eng_sta.execute(_find(), timeout=60)
        r_dev = eng_dev.execute(_find(), timeout=60)
        assert r_dev["stats"]["failed"] == 0
        _assert_same_entities(r_sta, r_dev)
        stats = eng_dev.dispatch_stats()
        assert stats["placements"]["device"] == 18   # rotate+flip+threshold
        d = stats["device"]
        assert d["entities_run"] == 6
        assert d["ops_run"] == 18
        assert d["fused_segments"] >= 1
        assert d["groups_run"] >= 1
        assert d["pending"] == 0
        assert d["compiles"] >= 1
        assert d["h2d_bytes"] > 0 and d["d2h_bytes"] > 0
    finally:
        eng_sta.shutdown()
        eng_dev.shutdown()


def test_fusion_off_reproduces_per_op_placement_and_results():
    # device_fuse_segments=False is the pre-fusion engine: the router
    # prices every device op cold (no residency discount), so ONLY the
    # pinned rotate lands there, each op is its own device group, and
    # responses stay byte-identical to the static engine
    eng_sta = _mk_engine()
    eng_dev = _mk_engine(dispatch="cost", device_backend=True,
                         device_fuse_segments=False,
                         cost_overrides=DEVICE_PIN,
                         device_max_wait_ms=50.0)
    try:
        _add_images(eng_sta)
        _add_images(eng_dev)
        r_sta = eng_sta.execute(_find(), timeout=60)
        r_dev = eng_dev.execute(_find(), timeout=60)
        assert r_dev["stats"]["failed"] == 0
        _assert_same_entities(r_sta, r_dev)
        stats = eng_dev.dispatch_stats()
        assert stats["placements"]["device"] == 6    # rotate, per entity
        d = stats["device"]
        assert d["entities_run"] == 6
        assert d["ops_run"] == 6
        assert d["fused_segments"] == 0
    finally:
        eng_sta.shutdown()
        eng_dev.shutdown()


def test_device_microbatches_respect_batch_size():
    eng = _mk_engine(dispatch="cost", device_backend=True,
                     device_batch_size=4, device_max_wait_ms=200.0,
                     cost_overrides=DEVICE_PIN)
    try:
        _add_images(eng, n=8)
        res = eng.execute(_find(ops=[{"type": "rotate", "k": 1}]),
                          timeout=60)
        assert res["stats"]["failed"] == 0
        d = eng.dispatch_stats()["device"]
        assert d["entities_run"] == 8
        assert d["groups_run"] >= 2       # 8 entities, groups capped at 4
    finally:
        eng.shutdown()


def test_device_udf_result_count_contract():
    # a device UDF returning fewer results than inputs must surface as
    # per-entity failures, never strand entities (the query would hang)
    register_udf("dev_short", lambda img: np.asarray(img))
    register_device_udf("dev_short", lambda imgs: [])     # always short
    eng = _mk_engine(dispatch="cost", device_backend=True,
                     device_max_wait_ms=100.0,
                     cost_overrides={"dev_short": {"device": 1e-9,
                                                   "native": 10.0,
                                                   "remote": 10.0}})
    try:
        _add_images(eng, n=4)
        res = eng.execute(_find(ops=[
            {"type": "udf", "options": {"id": "dev_short"}}]), timeout=30)
        assert res["stats"]["failed"] == 4
        assert eng.dispatch_stats()["device"]["errors"] >= 1
    finally:
        eng.shutdown()


def test_video_entities_fall_back_without_failing():
    # (T,H,W,C) payloads take the documented host fallback inside the
    # device worker; results must still match the static engine exactly
    eng_sta = _mk_engine()
    eng_dev = _mk_engine(dispatch="cost", device_backend=True,
                         cost_overrides=DEVICE_PIN,
                         device_max_wait_ms=50.0)
    try:
        rng = np.random.default_rng(9)
        for e in (eng_sta, eng_dev):
            clip = rng.uniform(0, 1, (3, 16, 16, 3)).astype(np.float32)
            e.add_entity("video", clip.copy(), {"category": "vid"})
            rng = np.random.default_rng(9)   # same clip for both engines
        q = _find("vid", ops=[{"type": "rotate", "k": 1}], kind="FindVideo")
        r_sta = eng_sta.execute(q, timeout=60)
        r_dev = eng_dev.execute(q, timeout=60)
        assert r_dev["stats"]["failed"] == 0
        _assert_same_entities(r_sta, r_dev)
        assert eng_dev.dispatch_stats()["device"]["entities_run"] == 1
    finally:
        eng_sta.shutdown()
        eng_dev.shutdown()


# -------------------------------------------- cancellation drains clean
def test_cancel_drains_inflight_device_microbatches():
    eng = _mk_engine(dispatch="cost", device_backend=True,
                     device_max_wait_ms=100.0,
                     cost_overrides=DEVICE_PIN)
    try:
        _add_images(eng, n=10)
        fut = eng.submit(_find())
        time.sleep(0.02)          # let some entities reach the device
        assert fut.cancel()
        deadline = time.monotonic() + 10
        while (eng.pool.inflight or eng.loop.queue1.qsize()
               or eng.device_backend.pending()) \
                and time.monotonic() < deadline:
            time.sleep(0.01)
        assert not eng.pool.inflight
        assert eng.loop.queue1.qsize() == 0
        assert eng.device_backend.pending() == 0
        assert eng.active_sessions() == 0
        # engine still healthy, device still serving
        res = eng.execute(_find(), timeout=60)
        assert res["stats"]["matched"] == 10
        assert res["stats"]["failed"] == 0
    finally:
        eng.shutdown()


# --------------------------------------------------- cost-model units
class _FixedBackend(Backend):
    def __init__(self, name, cost):
        self.name = name
        self.cost = cost
        self.placed = []

    def can_run(self, op):
        return True

    def estimate(self, op, payload_bytes):
        return self.cost

    def queue_depth(self):
        return 0

    def note_placed(self, op):
        self.placed.append(op.name)


def _unbound_device(**kw):
    """A DeviceBackend used purely as a cost model (never bound, no
    worker thread) with a deterministic, uncalibrated transfer model."""
    kw.setdefault("cost_model", DeviceCostModel(
        h2d_bytes_s=1e9, d2h_bytes_s=1e9, dispatch_latency_s=1e-4,
        compile_default_s=0.05))
    kw.setdefault("batch_size", 8)
    kw.setdefault("max_wait_s", 0.002)
    return DeviceBackend(calibrate=False, **kw)


def test_compile_amortization_decays_with_runs():
    tracker = OpCostTracker()
    dev = _unbound_device(tracker=tracker)
    op = make_op("blur", {"ksize": 5})
    cold = dev.estimate(op, payload_bytes=1000)
    dev._runs[op_signature(op)] = 9          # ten runs in: 0.05 -> 0.005
    warm = dev.estimate(op, payload_bytes=1000)
    assert cold - warm == pytest.approx(0.05 - 0.005, rel=1e-6)


def test_transfer_term_scales_with_payload():
    dev = _unbound_device()
    op = make_op("blur", {"ksize": 5})
    small = dev.estimate(op, payload_bytes=1_000)
    large = dev.estimate(op, payload_bytes=100_000_000)   # 100 MB
    # 100 MB over 1 GB/s both ways = 0.2 s of pure transfer
    assert large - small == pytest.approx(0.2, rel=1e-2)


def test_router_places_device_only_when_amortized_estimate_wins():
    tracker = OpCostTracker()
    dev = _unbound_device(tracker=tracker)
    native = _FixedBackend("native", 0.05)
    router = BackendRouter([native, dev], tracker=tracker)
    op = make_op("blur", {"ksize": 5})
    ops = [op]

    # cold device: the full 50 ms compile surcharge makes device lose
    # against 50 ms native (compile + wait + transfer tips it over)
    assert router.route(ops, payload_bytes=1000) == ["native"]

    # steady state: the op has run on device often (compile amortized
    # away) and its observed device EWMA is fast -> device wins
    dev._runs[op_signature(op)] = 500
    tracker.observe(op, 1e-4, kind="device")
    assert router.route(ops, payload_bytes=1000) == ["device"]

    # but a huge payload makes the transfer term dominate: back to native
    assert router.route(ops, payload_bytes=500_000_000) == ["native"]


def test_device_prior_amortizes_native_estimate_over_batch():
    # before any device run, the per-entity prior is native_est / B —
    # the same optimistic vectorization prior the batcher backend uses
    tracker = OpCostTracker()
    dev = _unbound_device(tracker=tracker, batch_size=8)
    op = make_op("blur", {"ksize": 5})
    tracker.observe(op, 0.8, kind="native")
    est = dev.estimate(op, payload_bytes=0)
    assert est == pytest.approx(
        0.002 / 2          # wait/2
        + 1e-4 / 8         # dispatch latency amortized over the batch
        + 0.8 / 8          # native estimate / batch_size prior
        + 0.05,            # cold compile surcharge
        rel=1e-3)


def test_can_run_native_table_and_device_udfs_only():
    dev = _unbound_device()
    assert dev.can_run(make_op("rotate", {"k": 1}))          # native table
    assert not dev.can_run(make_op("facedetect_box", {}, where="remote"))
    register_device_udf("dev_canrun", lambda imgs: list(imgs))
    assert dev.can_run(make_op("dev_canrun", {}, where="udf"))


def test_bad_platform_string_fails_before_any_thread_spawns():
    before = threading.active_count()
    with pytest.raises(RuntimeError):
        _mk_engine(dispatch="cost", device_backend="no_such_platform")
    assert threading.active_count() == before


def test_explicit_cpu_platform_string_resolves():
    eng = _mk_engine(dispatch="cost", device_backend="cpu",
                     cost_overrides=DEVICE_PIN)
    try:
        assert eng.device_backend.device.platform == "cpu"
        _add_images(eng, n=2)
        res = eng.execute(_find(), timeout=60)
        assert res["stats"]["failed"] == 0
    finally:
        eng.shutdown()


def test_device_override_rejected_under_native_dispatch_too():
    # under dispatch="native" a device pin would be silently inert (the
    # StaticRouter ignores overrides and no device backend can exist) —
    # it must fail at construction like the dispatch="cost" case
    before = threading.active_count()
    with pytest.raises(ValueError, match="device"):
        _mk_engine(dispatch="native", cost_overrides=DEVICE_PIN)
    assert threading.active_count() == before


def test_first_device_run_does_not_poison_the_device_ewma():
    # the first run of an op on the device is compile-contaminated and
    # must NOT seed the kind="device" EWMA — estimate() charges compile
    # via its own amortization term, so double-feeding it would leave
    # the backend permanently over-priced on the calibrated path
    eng = _mk_engine(dispatch="cost", device_backend=True,
                     device_max_wait_ms=50.0, cost_overrides=DEVICE_PIN)
    try:
        _add_images(eng, n=4)
        ops = [{"type": "rotate", "k": 1}]
        eng.execute(_find(ops=ops), timeout=60)       # first run: compile
        op = make_op("rotate", {"k": 1})
        assert not eng.cost_tracker.known(op, kind="device")
        eng.execute(_find(ops=ops), timeout=60)       # warm run: observed
        assert eng.cost_tracker.known(op, kind="device")
        # the pure-exec EWMA must sit far below the compile estimate
        assert eng.cost_tracker.estimate(op, kind="device") \
            < eng.device_backend.cost_model.compile_s()
    finally:
        eng.shutdown()
