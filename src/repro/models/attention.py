"""Multi-head attention with GQA, qk-norm, QKV bias, rope — covers every
assigned attention flavour (qwen3 qk_norm, qwen1.5/internvl2 bias,
granite/qwen GQA, whisper cross-attention, zamba2 shared blocks)."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import ShardingCtx
from repro.kernels import ops as kops
from repro.models import common
from repro.models.rope import apply_rope


def init_attention(kg: common.KeyGen, cfg: ArchConfig, dtype) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    qdim, kvdim = cfg.num_heads * hd, cfg.num_kv_heads * hd
    p = {
        "wq": common.normal(kg(), (d, qdim), dtype),
        "wk": common.normal(kg(), (d, kvdim), dtype),
        "wv": common.normal(kg(), (d, kvdim), dtype),
        "wo": common.normal(kg(), (qdim, d), dtype, std=(qdim ** -0.5) / max(cfg.num_layers, 1) ** 0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = common.zeros((qdim,), dtype)
        p["bk"] = common.zeros((kvdim,), dtype)
        p["bv"] = common.zeros((kvdim,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = common.ones((hd,), dtype)
        p["k_norm"] = common.ones((hd,), dtype)
    return p


def axes_attention(cfg: ArchConfig) -> dict:
    ax = {
        "wq": ("embed", "heads_fused"),
        "wk": ("embed", "kv_fused"),
        "wv": ("embed", "kv_fused"),
        "wo": ("heads_fused", "embed"),
    }
    if cfg.qkv_bias:
        ax["bq"] = ("heads_fused",)
        ax["bk"] = ("kv_fused",)
        ax["bv"] = ("kv_fused",)
    if cfg.qk_norm:
        ax["q_norm"] = (None,)
        ax["k_norm"] = (None,)
    return ax


def _project_qkv(p, x, xk, cfg: ArchConfig, sh: ShardingCtx):
    hd = cfg.resolved_head_dim
    B, S = x.shape[:2]
    Sk = xk.shape[1]
    q = x @ p["wq"]
    k = xk @ p["wk"]
    v = xk @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.num_heads, hd)
    k = k.reshape(B, Sk, cfg.num_kv_heads, hd)
    v = v.reshape(B, Sk, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = common.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = common.rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = sh(q, "batch", "seq", "act_heads", None)
    k = sh(k, "batch", "seq", "cache_heads", None)
    v = sh(v, "batch", "seq", "cache_heads", None)
    return q, k, v


def _pick_impl(seq: int) -> str:
    # naive materializes (Sq,Sk) logits — fine for short seq, flash beyond
    return "naive" if seq <= 1024 else "chunked"


def apply_attention(
    p: dict,
    x: jax.Array,                      # (B, S, d)
    *,
    cfg: ArchConfig,
    sh: ShardingCtx,
    positions: jax.Array | None = None,  # (S,) or (B,S)
    causal: bool = True,
    use_rope: bool = True,
    xk: jax.Array | None = None,         # cross-attention source
    kv_cache: dict | None = None,        # {"k": (B,Smax,Hkv,D), "v": ...}
    cache_index: jax.Array | None = None,  # scalar: write offset / valid len
    attn_impl: str | None = None,
) -> tuple[jax.Array, dict | None]:
    """Returns (output, updated kv_cache or None).

    Modes:
    - no cache: full (causal) attention over x (train / encoder).
    - cache + S>=1: prefill-into-cache or single-token decode; new keys are
      written at ``cache_index`` and attention spans the first
      ``cache_index + S`` cache slots.
    """
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    xk_src = x if xk is None else xk
    q, k, v = _project_qkv(p, x, xk_src, cfg, sh)

    rope_on = use_rope and cfg.pos_scheme == "rope" and xk is None
    if rope_on:
        if positions is None:
            base = 0 if cache_index is None else cache_index
            positions = base + jnp.arange(S)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if kv_cache is not None and xk is None:
        idx = jnp.asarray(0 if cache_index is None else cache_index, jnp.int32)
        kc = jax.lax.dynamic_update_slice(kv_cache["k"], k.astype(kv_cache["k"].dtype),
                                          (0, idx, 0, 0))
        vc = jax.lax.dynamic_update_slice(kv_cache["v"], v.astype(kv_cache["v"].dtype),
                                          (0, idx, 0, 0))
        kc = sh(kc, "batch", "cache_seq", "cache_heads", None)
        vc = sh(vc, "batch", "cache_seq", "cache_heads", None)
        new_cache = {"k": kc, "v": vc}
        if S == 1:
            out = kops.decode_attention(q, kc, vc, idx + 1)
        else:
            # prefill into cache: with causal masking at offset ``idx`` the
            # not-yet-written cache tail (> idx+S) is never attended.
            impl = attn_impl or _pick_impl(kc.shape[1])
            if impl == "naive":
                from repro.kernels import ref as kref
                valid = jnp.broadcast_to(idx + S, (B,))
                out = kref.naive_attention(q, kc, vc, causal=causal,
                                           kv_len=valid, q_offset=idx)
            else:
                from repro.kernels.flash_vjp import flash_attention as flash_vjp
                out = flash_vjp(q, kc, vc, idx, True, None, 512, 1024)
    else:
        impl = attn_impl or _pick_impl(max(S, xk_src.shape[1]))
        if impl == "chunked":
            # flash with flash-backward (O(block^2) memory both passes)
            from repro.kernels.flash_vjp import flash_attention as flash_vjp
            out = flash_vjp(q, k, v, 0, causal, None, 512, 1024)
        else:
            out = kops.flash_attention(q, k, v, causal=causal, impl=impl)

    out = sh(out, "batch", "seq", "act_heads", None)
    out = out.reshape(B, S, cfg.num_heads * hd)
    return out @ p["wo"], new_cache


def apply_cross_attention_cached(
    p: dict,
    x: jax.Array,            # (B, S, d) decoder hidden
    cross_cache: dict,       # {"k": (B,Se,Hkv,D), "v": ...} precomputed from encoder
    *,
    cfg: ArchConfig,
    sh: ShardingCtx,
) -> jax.Array:
    """Decode-time cross-attention: q from x, K/V from the prefill cache."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["wq"]
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(B, S, cfg.num_heads, hd)
    if cfg.qk_norm:
        q = common.rms_norm(q, p["q_norm"], cfg.norm_eps)
    out = kops.decode_attention(q, cross_cache["k"], cross_cache["v"],
                                cross_cache["k"].shape[1])
    out = out.reshape(B, S, cfg.num_heads * hd)
    return out @ p["wo"]


def make_cross_cache(p: dict, enc: jax.Array, cfg: ArchConfig, sh: ShardingCtx) -> dict:
    """Precompute K/V of the encoder output for decoder cross-attention."""
    B, Se, _ = enc.shape
    hd = cfg.resolved_head_dim
    k = enc @ p["wk"]
    v = enc @ p["wv"]
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    k = k.reshape(B, Se, cfg.num_kv_heads, hd)
    v = v.reshape(B, Se, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        k = common.rms_norm(k, p["k_norm"], cfg.norm_eps)
    return {"k": sh(k, "batch", "seq", "cache_heads", None),
            "v": sh(v, "batch", "seq", "cache_heads", None)}
