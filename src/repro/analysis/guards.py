"""Guarded-by discipline + blocking-calls-under-lock.

``guarded-by`` semantics: an attribute annotated ``# guarded-by: L``
in class ``C`` may be touched only

* inside a ``with self.L:`` block (on the same instance),
* inside a ``*_locked``-suffixed method (the repo convention for
  "caller holds the lock" — call sites of those methods are checked
  instead: they must hold *some* lock of the class), or
* inside ``__init__`` (the object is not yet shared).

Only ``self.<attr>`` accesses are checked — cross-object accesses
(``other._x``) are out of scope for a lexical checker and rare by
convention.  Subclass methods are checked against annotations merged
down the harvested MRO.

``blocking-under-lock`` flags calls that can block indefinitely while
any lock is held: ``time.sleep``, thread ``join``, zero-arg
``Queue.get`` / bounded-``Queue.put`` without timeout, zero-arg
``future.result()``, socket ``recv/sendall/accept/connect``, untimed
``.wait()`` (except a condition variable waiting on the *only* held
lock, which releases it), and user callbacks (``self.on_*`` /
``self._on_*`` or bare ``cb()``/``callback()``).  The check follows
same-instance calls transitively, so a helper that sleeps is flagged
at the call site that holds the lock.
"""
from __future__ import annotations

import re

from repro.analysis.harvest import CallSite, ClassFacts, ModuleFacts
from repro.analysis.locks import LockAnalysis
from repro.analysis.model import Finding

CALLBACK_SELF = re.compile(r"^_?on_[a-z0-9_]+$")
CALLBACK_NAME = frozenset({"cb", "callback", "hook"})
SOCKET_BLOCKING = frozenset({"sendall", "recv", "recv_into", "accept",
                             "connect"})
THREADISH = re.compile(r"thread|worker|writer|reader", re.IGNORECASE)


def _merged_guards(la: LockAnalysis, cls_name: str) -> dict:
    guards: dict = {}
    for cf in reversed(la.mro(cls_name)):
        for attr, (lock, line) in cf.guards.items():
            guards[attr] = lock
    return guards


def _class_lock_attrs(la: LockAnalysis, cls_name: str) -> set:
    attrs: set = set()
    for cf in la.mro(cls_name):
        attrs.update(cf.lock_attrs)
    return attrs


def _holds(held: tuple, lock_attr: str) -> bool:
    return ("self", lock_attr) in held


def _queue_bounded(la: LockAnalysis, cls_name: str | None,
                   attr: str) -> bool | None:
    if cls_name is None:
        return None
    for cf in la.mro(cls_name):
        if attr in cf.queue_attrs:
            return cf.queue_attrs[attr]
    return None


def _blocking_reason(site: CallSite, la: LockAnalysis,
                     cls_name: str | None) -> str | None:
    """Why this call site is intrinsically blocking, else None.

    Judged independently of held locks; the caller decides whether a
    lock is held.  The one lock-sensitive case (condition-variable
    wait on the sole held lock) is handled by the caller.
    """
    name, kind, recv = site.name, site.kind, site.recv
    timed = "timeout" in site.kwnames
    if name == "sleep" and (kind == "name"
                            or (kind == "attr" and recv == ("name", "time"))):
        return "time.sleep()"
    if kind == "attr" and name == "join":
        target = recv[1] if recv[0] in ("selfattr", "name") else ""
        if THREADISH.search(target):
            return f"{target}.join()"
        return None
    if kind == "attr" and name == "result" and site.n_args == 0 \
            and not timed:
        return f"{recv[1] or 'future'}.result() without timeout"
    if kind == "attr" and name == "get" and site.n_args == 0 and not timed:
        # dict.get always takes a key; a zero-arg .get() is a queue
        return f"{recv[1] or '?'}.get() without timeout"
    if kind == "attr" and name == "put" and not timed \
            and recv[0] == "selfattr":
        if _queue_bounded(la, cls_name, recv[1]):
            return f"{recv[1]}.put() on a bounded queue without timeout"
        return None
    if kind == "attr" and name in SOCKET_BLOCKING:
        return f"socket {name}()"
    if kind == "self" and CALLBACK_SELF.match(name):
        return f"user callback self.{name}()"
    if kind == "name" and name in CALLBACK_NAME:
        return f"user callback {name}()"
    return None


def _wait_reason(site: CallSite) -> str | None:
    """Untimed ``.wait()``/``.wait_for()`` handling, held-sensitive:
    waiting on the condition variable that is the *only* held lock is
    the normal cv idiom (wait releases it); anything else held, or an
    untimed wait on a non-held object (an Event), blocks for real."""
    if site.kind != "attr" or site.name not in ("wait", "wait_for"):
        return None
    timed = "timeout" in site.kwnames or \
        (site.name == "wait" and site.n_args >= 1) or \
        (site.name == "wait_for" and site.n_args >= 2)
    recv_tok = ("self", site.recv[1]) if site.recv[0] == "selfattr" else None
    if recv_tok is not None and recv_tok in site.held:
        others = [t for t in site.held if t != recv_tok]
        if others:
            return (f"{site.recv[1]}.{site.name}() releases only "
                    f"{site.recv[1]} — still holding "
                    + ", ".join(t[1] for t in others))
        return None
    if not timed:
        return f"untimed {site.name}() while holding a lock"
    return None


class GuardAnalysis:
    def __init__(self, la: LockAnalysis):
        self.la = la

    def run(self) -> list[Finding]:
        out: list[Finding] = []
        blocking = self._transitive_blocking()
        for key, (mf, cf, facts) in self.la.funcs.items():
            if cf is not None:
                out.extend(self._check_guards(mf, cf, facts))
                out.extend(self._check_locked_calls(mf, cf, facts))
            out.extend(self._check_blocking(mf, cf, facts, blocking))
        return out

    # ----------------------------------------------------- guarded-by
    def _check_guards(self, mf: ModuleFacts, cf: ClassFacts,
                      facts) -> list[Finding]:
        if facts.name == "__init__" or facts.name.endswith("_locked"):
            return []
        guards = _merged_guards(self.la, cf.name)
        if not guards:
            return []
        out = []
        seen = set()
        for acc in facts.accesses:
            lock = guards.get(acc.attr)
            if lock is None or _holds(acc.held, lock):
                continue
            mode = "write" if acc.write else "read"
            dedup = (acc.attr, acc.line, mode)
            if dedup in seen:
                continue
            seen.add(dedup)
            out.append(Finding(
                rule="guarded-by", severity="error",
                path=mf.path, line=acc.line, scope=facts.qualname,
                subject=f"{cf.name}.{acc.attr}:{mode}:{facts.qualname}",
                message=(f"{mode} of {acc.attr} (guarded-by {lock}) "
                         f"outside `with self.{lock}:`")))
        return out

    def _check_locked_calls(self, mf: ModuleFacts, cf: ClassFacts,
                            facts) -> list[Finding]:
        """``self.foo_locked()`` requires some lock of the class held."""
        if facts.name == "__init__" or facts.name.endswith("_locked"):
            return []
        lock_attrs = _class_lock_attrs(self.la, cf.name)
        if not lock_attrs:
            return []
        out = []
        for site in facts.calls:
            if site.kind != "self" or not site.name.endswith("_locked"):
                continue
            if self.la.resolve_self_method(cf.name, site.name) is None:
                continue
            held_attrs = {t[1] for t in site.held if t[0] == "self"}
            if held_attrs & lock_attrs:
                continue
            out.append(Finding(
                rule="guarded-by", severity="error",
                path=mf.path, line=site.line, scope=facts.qualname,
                subject=f"call-unlocked:{cf.name}.{site.name}",
                message=(f"self.{site.name}() called without holding any "
                         f"lock of {cf.name} (the _locked suffix means "
                         f"the caller must hold it)")))
        return out

    # ---------------------------------------------- blocking-under-lock
    def _transitive_blocking(self) -> dict:
        """func key -> (reason, depth) if the function blocks directly
        or through same-instance calls."""
        block: dict[str, str] = {}
        for key, (mf, cf, facts) in self.la.funcs.items():
            for site in facts.calls:
                reason = _blocking_reason(site, self.la,
                                          cf.name if cf else None)
                if reason is None and site.kind == "attr" \
                        and site.name in ("wait", "wait_for"):
                    timed = "timeout" in site.kwnames or site.n_args >= 1
                    if not timed:
                        reason = f"untimed {site.name}()"
                if reason is not None:
                    block.setdefault(key, reason)
                    break
        callees: dict[str, set] = {}
        for key, (mf, cf, facts) in self.la.funcs.items():
            callees[key] = set()
            if cf is None:
                continue
            for site in facts.calls:
                if site.kind != "self":
                    continue
                tgt = self.la.resolve_self_method(cf.name, site.name)
                if tgt is not None:
                    callees[key].add(tgt)
        changed = True
        while changed:
            changed = False
            for key, outs in callees.items():
                if key in block:
                    continue
                for g in outs:
                    if g in block:
                        block[key] = f"calls {g.split(':')[-1]} " \
                                     f"({block[g]})"
                        changed = True
                        break
        return block

    def _check_blocking(self, mf: ModuleFacts, cf, facts,
                        block: dict) -> list[Finding]:
        out = []
        cls_name = cf.name if cf is not None else None
        seen = set()
        for site in facts.calls:
            if not site.held:
                continue
            reason = _blocking_reason(site, self.la, cls_name)
            if reason is None:
                reason = _wait_reason(site)
            if reason is None and site.kind == "self" and cf is not None:
                tgt = self.la.resolve_self_method(cf.name, site.name)
                if tgt is not None and tgt in block \
                        and not site.name.endswith("_locked"):
                    reason = (f"self.{site.name}() blocks transitively: "
                              f"{block[tgt]}")
            if reason is None:
                continue
            held = ", ".join(t[1] for t in site.held)
            dedup = (site.name, site.line)
            if dedup in seen:
                continue
            seen.add(dedup)
            out.append(Finding(
                rule="blocking-under-lock", severity="error",
                path=mf.path, line=site.line, scope=facts.qualname,
                subject=f"{facts.qualname}:{site.name}:{held}",
                message=f"{reason} while holding {held}"))
        return out
