"""Separable Gaussian blur Pallas TPU kernel.

The wrapper reflect-101 pads the image by ``ksize//2`` on both spatial
axes (matching OpenCV's default border), then the kernel computes a
*valid* separable convolution over row bands:

  grid = (batch, H/block_rows); each step sees its own band plus the next
  band (two refs on the same padded input, index_maps i and i+1) so the
  vertical taps never leave VMEM.  Taps are a static unroll of
  shift-multiply-adds — pure VPU work with no gather, which is the
  TPU-native way to express a small stencil.

VMEM at 1080p, block_rows=128, ksize<=31: 2 bands x 128 x (1920+30) x 3
x 4B ~= 6 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.ref import gaussian_kernel_1d, _reflect101_pad


def _blur_kernel(cur_ref, nxt_ref, o_ref, *, ky, kx, block_rows, out_h):
    i = pl.program_id(1)
    band = jnp.concatenate([cur_ref[0], nxt_ref[0]], axis=0).astype(jnp.float32)
    # vertical pass: rows [0, block_rows) of output need rows [l, l+K) of band
    K = len(ky)
    tmp = ky[0] * band[0:block_rows]
    for t in range(1, K):
        tmp = tmp + ky[t] * band[t:t + block_rows]
    # horizontal pass (width padded by K-1): out cols [0, W)
    W = o_ref.shape[2]
    out = kx[0] * tmp[:, 0:W]
    for t in range(1, K):
        out = out + kx[t] * tmp[:, t:t + W]
    o_ref[0] = out.astype(o_ref.dtype)


def gaussian_blur_pallas(
    img: jax.Array,  # (N, H, W, C) or (H, W, C)
    ksize: int,
    sigma_x: float,
    sigma_y: float | None = None,
    *,
    block_rows: int = 128,
    interpret: bool = False,
) -> jax.Array:
    if sigma_y is None:
        sigma_y = sigma_x
    squeeze = img.ndim == 3
    if squeeze:
        img = img[None]
    N, H, W, C = img.shape
    pad = ksize // 2
    block_rows = max(min(block_rows, H), 2 * pad if pad else 1)

    ky = tuple(float(x) for x in gaussian_kernel_1d(ksize, sigma_y))
    kx = tuple(float(x) for x in gaussian_kernel_1d(ksize, sigma_x))

    x = _reflect101_pad(_reflect101_pad(img, pad, axis=-3), pad, axis=-2)
    # pad rows up to a multiple of block_rows (+ one extra band for `next`)
    rows_needed = ((H + block_rows - 1) // block_rows + 1) * block_rows + 2 * pad
    x = jnp.pad(x, ((0, 0), (0, rows_needed - x.shape[1]), (0, 0), (0, 0)))
    nb = H // block_rows + (1 if H % block_rows else 0)
    wp = W + 2 * pad

    kernel = functools.partial(_blur_kernel, ky=ky, kx=kx,
                               block_rows=block_rows, out_h=H)
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel"))
    out = pl.pallas_call(
        kernel,
        grid=(N, nb),
        in_specs=[
            pl.BlockSpec((1, block_rows, wp, C), lambda n, i: (n, i, 0, 0)),
            pl.BlockSpec((1, block_rows, wp, C), lambda n, i: (n, i + 1, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_rows, W, C), lambda n, i: (n, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((N, nb * block_rows, W, C), img.dtype),
        interpret=interpret,
        **kwargs,
    )(x, x)
    out = out[:, :H]
    return out[0] if squeeze else out
