"""Gradient compression for the data-parallel reduction.

Two mechanisms, both verifiable in the dry-run HLO:

1. bf16 gradient reduction (default ON via TrainConfig.grad_reduce_dtype):
   the backward emits bf16 gradients, so GSPMD's DP all-reduce moves half
   the bytes.  Zero code here — it falls out of dtype flow — but the
   collective-bytes delta shows up in EXPERIMENTS.md section Perf.

2. int8 + error feedback (this module): for pure-DP meshes (model
   replicated, e.g. the paper-style "kappa remote servers" scale-out),
   ``compressed_psum_int8`` implements a two-phase quantized reduction
   inside shard_map: per-chunk int8 quantization -> all_to_all
   (reduce-scatter phase, int8 on the wire) -> local f32 accumulate ->
   re-quantize -> all_gather (int8 on the wire).  Wire bytes ~ 0.5x f32
   all-reduce's 2x payload => ~4x compression.  ``ErrorFeedback`` keeps
   the quantization residual and folds it into the next step (Karimireddy
   et al.), preserving convergence.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def _quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization; returns (q, scale)."""
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_int8(x: jax.Array, axis_name: str) -> jax.Array:
    """Mean over ``axis_name`` with int8 wire traffic (call inside
    shard_map).  x: (1, size) member-local gradient vector, size divisible
    by the axis size.  Returns (1, size): every member holds the mean.

    Wire traffic per member: size/4 bytes (all-to-all of int8 chunks) +
    size/4 bytes (all-gather of re-quantized means) vs 2*size*4 bytes for
    a ring f32 all-reduce => ~8x wire compression (4x vs bf16)."""
    n = jax.lax.axis_size(axis_name)
    v = x[0]
    cs = v.shape[0] // n
    chunks = v.reshape(n, cs)
    q, scale = _quantize_int8(chunks)             # one scale per member
    # phase 1 (reduce-scatter shape): peer j receives every member's chunk j
    q_t = jax.lax.all_to_all(q, axis_name, split_axis=0, concat_axis=0,
                             tiled=True)          # (n, cs): row i = peer i's my-chunk
    scales = jax.lax.all_gather(scale, axis_name) # (n,)
    mean_chunk = jnp.sum(q_t.astype(jnp.float32)
                         * scales[:, None], axis=0) / n   # (cs,)
    # phase 2: publish the owned mean chunk
    q2, s2 = _quantize_int8(mean_chunk)
    gathered = jax.lax.all_gather(q2, axis_name)          # (n, cs) int8
    s_all = jax.lax.all_gather(s2, axis_name)             # (n,)
    out = gathered.astype(jnp.float32) * s_all[:, None]
    return out.reshape(1, n * cs)


def make_compressed_grad_reducer(mesh: Mesh, axis: str = "data"):
    """Returns reduce(grads_tree): input leaves are (n, ...) arrays sharded
    ``P(axis)`` — row i is member i's local gradient — output is the same
    shape with every row holding the int8-wire mean.  For pure-DP meshes
    (model replicated over ``axis``), e.g. the paper-style kappa-server
    scale-out in examples/scaleout_train.py."""
    n = mesh.devices.shape[list(mesh.axis_names).index(axis)]

    def reduce_tree(grads):
        def one(g):
            rows, size = g.shape[0], int(np.prod(g.shape[1:]))
            assert rows == n, f"leading dim {rows} != DP size {n}"
            pad = (-size) % n
            flat = g.reshape(n, size).astype(jnp.float32)
            if pad:
                flat = jnp.concatenate(
                    [flat, jnp.zeros((n, pad), jnp.float32)], axis=1)

            fn = shard_map(
                functools.partial(compressed_psum_int8, axis_name=axis),
                mesh=mesh,
                in_specs=P(axis, None),
                out_specs=P(axis, None),
            )
            red = fn(flat)
            return red[:, :size].reshape(g.shape)

        return jax.tree.map(one, grads)

    return reduce_tree


class ErrorFeedback:
    """e_{t} = g_t + e_{t-1} - Q(g_t + e_{t-1}); carried in the train state."""

    @staticmethod
    def init(params) -> Any:
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    @staticmethod
    def apply(grads, ef_state, quantize=_quantize_int8):
        corrected = jax.tree.map(
            lambda g, e: g.astype(jnp.float32) + e, grads, ef_state)

        def q_dq(x):
            q, s = quantize(x)
            return _dequantize(q, s)

        sent = jax.tree.map(q_dq, corrected)
        new_ef = jax.tree.map(lambda c, s: c - s, corrected, sent)
        return sent, new_ef
