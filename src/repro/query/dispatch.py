"""Cost-model multi-backend dispatch (ROADMAP: "multi-backend dispatch").

The paper's planner hard-codes placement — "native unless the op says
remote".  This module makes placement a *decision*: a per-op cost model
estimates how long each op would take on each available backend, and a
:class:`BackendRouter` the planner consults at ``expand`` time assigns
every op of an entity's chain to the backend where it is estimated to
finish soonest, splitting one chain into native → remote → batcher
segments when that wins (handoff rides the existing Queue_2 / Thread_3
reply path).

Backends (all behind the common :class:`Backend` protocol):

- **native**   — the event loop's native worker pool (Queue_1);
- **remote**   — the κ remote-server pool (rides the existing per-entity
  dispatch and cross-session coalescing paths unchanged);
- **batcher**  — grouped UDF execution
  (:class:`repro.serving.batcher.UDFBatcherBackend`): ops with a
  registered batched variant (``register_batched_udf`` — e.g. model
  UDFs, whose GroupBatcher amortizes prefill+decode over a group);
- **device**   — accelerator execution
  (:class:`repro.query.device_backend.DeviceBackend`, built only when
  the engine enables ``device_backend``): native-table ops and ops with
  a registered device UDF (``register_device_udf``) run as jit-compiled
  JAX on the device, micro-batched; the first backend whose cost adds
  host↔device transfer and one-time jit-compile amortization terms.

Cost model (ARCHITECTURE.md "Dispatch" has the diagram)::

    native(op)  = op_est · (1 + util)          + backlog_native  / W
    remote(op)  = transport.cost(nbytes) + op_est
                  + pending_entities · lat_est / κ + backlog_remote / κ
    batcher(op) = wait/2 + op_est / G          + backlog_batcher
    device(op)  = wait/2 + transfer(nbytes, B) + op_est_dev
                  + compile_s / (1 + runs)     + backlog_device
    device_resident(op) = op_est_dev           (segment fusion on)

The last line is the *segment* pricing: a backend that declares
``resident_capable`` (the device backend with ``fuse_segments`` on)
charges its full ``estimate`` — batching wait, transfer, compile
amortization — only on the op that ENTERS a run of consecutive
placements, and ``estimate_resident`` (pure marginal compute) for every
subsequent op that stays.  The DP therefore prices device *segments*,
not ops: transfer and dispatch amortize over the whole fused segment,
compile over its run count, which widens the regime where the device
wins exactly as fusing the execution does.

where ``op_est`` is an EWMA of observed per-op execution seconds
(:class:`OpCostTracker`, calibrated online by the native workers and the
batcher), ``util`` is the native pool's recent BusyMeter utilization,
``lat_est`` the remote pool's amortized per-entity latency estimate, κ
the live server count, W the native worker count, G the batcher group
size, and each ``backlog`` a leaky-bucket ledger of work the router
itself recently placed on that backend (so one expand's fan-out spreads
across backends instead of herding onto the first-cheapest one).

Routing minimizes total estimated cost over the chain with a dynamic
program that charges ``handoff_s`` for every backend switch (a switch
costs a Queue_2 hop and possibly a batching window), entered at the
native backend — entities always start life on Queue_1.  Chains resumed
from a result-cache prefix hit are routed from their resume point only
(``start=op_index``).

``cost_overrides={op_name: {backend: seconds}}`` pins estimates for
benchmarks and tests (forced cost regimes); an override never makes a
backend eligible that ``can_run`` rejects.

The default engine (``dispatch="static"``) builds none of this: entities
carry ``route=None`` and the event loop reproduces the paper's rule
byte-identically.
"""
from __future__ import annotations

import abc
import queue
import threading
import time
from typing import Optional

from repro.core.result_cache import op_signature

NATIVE = "native"
REMOTE = "remote"
BATCHER = "batcher"
DEVICE = "device"

_INF = float("inf")


def validate_overrides(overrides: dict | None,
                       known=(NATIVE, REMOTE, BATCHER, DEVICE)) -> dict:
    """Shape-check a ``cost_overrides`` mapping ({op_name: {backend:
    seconds}}).  The engine calls this BEFORE spawning any pool/loop/
    batcher threads, so a malformed knob raises without leaking them."""
    overrides = overrides or {}
    for op_name, per_backend in overrides.items():
        if not isinstance(per_backend, dict):
            raise ValueError(
                f"cost_overrides[{op_name!r}] must be a dict "
                f"{{backend: seconds}}, got {per_backend!r}")
        unknown = set(per_backend) - set(known)
        if unknown:
            raise ValueError(
                f"cost_overrides[{op_name!r}] names unknown "
                f"backend(s) {sorted(unknown)}; known: {sorted(known)}")
    return overrides


def collect_microbatch(inbox, first, *, size: int, max_wait_s: float,
                       clock=time.monotonic, stop=None):
    """Shared micro-batch gather loop for offload backends (batcher and
    device workers): collect up to ``size`` items from ``inbox``
    starting with ``first``, holding the group open at most
    ``max_wait_s`` from the first member's arrival.  Returns
    ``(group, saw_stop)`` — ``saw_stop`` when the ``stop`` sentinel was
    drained mid-collection, so the worker finishes this group and then
    exits."""
    group = [first]
    deadline = clock() + max_wait_s
    while len(group) < size:
        remaining = deadline - clock()
        if remaining <= 0:
            break
        try:
            nxt = inbox.get(timeout=remaining)
        except queue.Empty:
            break
        if nxt is stop:
            return group, True
        group.append(nxt)
    return group, False


OFFLOAD_STOP = object()   # shared poison pill for offload-backend inboxes


class OffloadInboxMixin:
    """Inbox lifecycle shared by the offload backends
    (``UDFBatcherBackend``, ``DeviceBackend``): a locked submit gate so
    no entity can land in the inbox after shutdown's close (a bare
    closed-check-then-put races the final drain sweep — a submitter
    descheduled between check and put would strand its entity in a dead
    inbox), the poison-pill-then-drain shutdown, and the post-join
    sweep.  Subclasses call :meth:`_init_inbox` in ``__init__``,
    provide ``name`` and ``_run_groups(entities)``, and their worker
    loops treat ``OFFLOAD_STOP`` as the pill, calling
    :meth:`_drain_after_stop` when they see it."""

    def _init_inbox(self) -> None:
        self.inbox: queue.Queue = queue.Queue()
        self._thread: Optional[threading.Thread] = None
        self._closed = threading.Event()
        self._submit_gate = threading.Lock()
        self.fault_injector = None   # set by the engine (chaos testing)

    def _maybe_fault(self) -> None:
        """Deterministic fault-injection hook for offload workers
        (:class:`repro.distributed.fault.FaultInjector`, site
        ``backend:<name>``): a latency fault sleeps here; every other
        kind raises :class:`~repro.distributed.fault.TransientError`,
        which the worker's existing per-entity error path reports — so
        an injected fault degrades exactly like a real one."""
        fi = self.fault_injector
        if fi is None:
            return
        fault = fi.decide(f"backend:{self.name}")
        if fault is None:
            return
        if fault.kind == "latency":
            time.sleep(fault.latency_s)
            return
        from repro.distributed.fault import TransientError
        raise TransientError(
            f"injected {fault.kind} fault in {self.name} backend")

    def submit(self, entity) -> None:
        """Thread_3 hands an entity whose current op is routed here.
        Raises ``RuntimeError`` once shutdown has begun — a late
        enqueue must fail loudly (the event loop converts it into a
        per-entity failure), never sit silently in a dead inbox."""
        with self._submit_gate:
            if self._closed.is_set():
                raise RuntimeError(f"{self.name} backend is shut down")
            self.inbox.put(entity)

    def pending(self) -> int:
        return self.inbox.qsize()

    def shutdown(self, timeout: float = 5.0) -> None:
        """Poison-pill-then-drain shutdown: mark the backend closed
        under the submit gate (so the close is atomic with any
        in-progress put and late ``submit`` raises), queue the pill,
        and join.  The worker finishes its current micro-batch, then
        drains and *executes* everything accepted before the close —
        work already admitted is never silently dropped, so
        ``engine.shutdown()`` stays deterministic with sessions still
        in flight.  Idempotent."""
        with self._submit_gate:
            first_close = not self._closed.is_set()
            self._closed.set()
        if self._thread is None:
            return
        if first_close:
            self.inbox.put(OFFLOAD_STOP)
        self._thread.join(timeout)
        if not self._thread.is_alive():
            # the worker is joined, so this final sweep on the caller's
            # thread is race-free (and a repeat shutdown re-sweeps
            # harmlessly: the inbox is empty)
            self._drain_after_stop()

    def _drain_after_stop(self) -> None:
        """Execute entities still queued around the poison pill — work
        accepted before the close is never silently dropped (cancelled
        sessions' members are discarded in O(1) by the batch runner)."""
        leftover = []
        while True:
            try:
                nxt = self.inbox.get_nowait()
            except queue.Empty:
                break
            if nxt is not OFFLOAD_STOP:
                leftover.append(nxt)
        if leftover:
            self._run_groups(leftover)


class OpCostTracker:
    """EWMA of observed per-op execution seconds, keyed by canonical op
    signature.  ``kind="native"`` samples come from the native workers
    (pure op compute — also the best available estimate for the op's
    compute on a remote server); ``kind="batched"`` samples are the
    *amortized per-entity* seconds of a batcher group run."""

    def __init__(self, default_s: float = 1e-3, alpha: float = 0.25):
        self.default_s = default_s
        self.alpha = alpha
        self._lock = threading.Lock()
        self._est: dict[str, dict[tuple, float]] = {          # guarded-by: _lock
            "native": {}, "batched": {}, "device": {}}
        self._out_bytes: dict[tuple, float] = {}   # guarded-by: _lock

    def observe(self, op, seconds: float, kind: str = "native",
                out_bytes: int | None = None):
        key = op_signature(op)
        with self._lock:
            table = self._est[kind]
            prev = table.get(key)
            table[key] = (seconds if prev is None
                          else (1 - self.alpha) * prev + self.alpha * seconds)
            if out_bytes is not None:
                prev_b = self._out_bytes.get(key)
                self._out_bytes[key] = (
                    float(out_bytes) if prev_b is None
                    else (1 - self.alpha) * prev_b + self.alpha * out_bytes)

    def estimate(self, op, kind: str = "native",
                 default: float | None = None) -> float:
        with self._lock:
            est = self._est[kind].get(op_signature(op))
        return est if est is not None else (
            default if default is not None else self.default_s)

    def out_bytes(self, op, default: float = 0.0) -> float:
        """EWMA of the op's observed OUTPUT payload size — lets the
        router thread realistic payloads through a chain (a post-resize
        remote op is costed on the small intermediate, not the original
        blob)."""
        with self._lock:
            b = self._out_bytes.get(op_signature(op))
        return b if b is not None else default

    def known(self, op, kind: str = "native") -> bool:
        with self._lock:
            return op_signature(op) in self._est[kind]

    def mean_estimate(self, kind: str = "native") -> float | None:
        """Mean of the calibrated per-op estimates — the admission
        controller's per-entity service-time fallback when no
        completion-rate sample exists yet.  None when nothing has been
        observed."""
        with self._lock:
            table = self._est[kind]
            if not table:
                return None
            return sum(table.values()) / len(table)

    def snapshot(self) -> dict:
        with self._lock:
            return {kind: dict(table) for kind, table in self._est.items()}


class LoadLedger:
    """Leaky bucket of *projected* work-seconds the router has placed on
    one backend.  Placements add their estimated seconds; the bucket
    drains at the backend's parallel capacity (``drain_rate()``
    work-seconds per wall second), so the queue-wait term a later
    placement sees is ``backlog_s() / capacity`` — the feedback that
    spreads a single expand's fan-out across backends."""

    def __init__(self, drain_rate, clock=time.monotonic):
        self._drain_rate = drain_rate
        self._clock = clock
        self._lock = threading.Lock()
        self._backlog = 0.0       # guarded-by: _lock
        self._last = clock()      # guarded-by: _lock

    def _decay_locked(self):
        now = self._clock()
        self._backlog = max(0.0, self._backlog
                            - (now - self._last) * max(1e-9, self._drain_rate()))
        self._last = now

    def add(self, seconds: float):
        with self._lock:
            self._decay_locked()
            self._backlog += max(0.0, seconds)

    def backlog_s(self) -> float:
        with self._lock:
            self._decay_locked()
            return self._backlog


class Backend(abc.ABC):
    """What the router needs from an execution backend.  Execution
    mechanics stay where they live (event loop / remote pool / batcher
    worker / device worker); this protocol only exposes the
    placement-relevant surface.  Implementations:
    :class:`NativeBackend`, :class:`RemoteBackend`,
    :class:`repro.serving.batcher.UDFBatcherBackend`, and
    :class:`repro.query.device_backend.DeviceBackend` (the latter two
    satisfy the protocol structurally rather than by subclassing —
    the router only requires the four methods and ``name``).

    The one hard semantic contract: backends are *interchangeable* —
    every backend that ``can_run`` an op must produce a result
    equivalent to every other backend's (the router is free to place
    the same op differently on every call)."""

    name: str = "?"

    #: Whether consecutive placements on this backend keep the payload
    #: resident (no per-op transfer/entry cost after the first).  The
    #: router then prices in-segment ops with :meth:`estimate_resident`.
    resident_capable: bool = False

    @abc.abstractmethod
    def can_run(self, op) -> bool:
        """Whether this backend can execute ``op`` at all.  A cost
        override never bypasses this — pinning an op cheap on a backend
        that cannot run it still costs ``inf`` there."""

    @abc.abstractmethod
    def estimate(self, op, payload_bytes: int) -> float:
        """Estimated seconds for ``op`` on this backend right now,
        including queueing/transport/amortization terms.
        ``payload_bytes`` is the router's estimate of the op's INPUT
        payload (threaded through the chain from observed output-size
        EWMAs), for backends with a transfer term."""

    def estimate_resident(self, op, payload_bytes: int) -> float:
        """Estimated seconds for ``op`` when the PREVIOUS op already ran
        here and the backend is ``resident_capable`` — the marginal cost
        of extending the resident segment by one op (no entry costs).
        Default: same as :meth:`estimate` (no residency advantage)."""
        return self.estimate(op, payload_bytes)

    @abc.abstractmethod
    def queue_depth(self) -> int:
        """Entities currently waiting on this backend (surfaced in
        ``dispatch_stats()["queue_depths"]``)."""

    def note_placed(self, op):
        """Router feedback: ``op`` was just routed here; add its
        projected work to the backend's leaky-bucket ledger so one
        expand's fan-out spreads across backends instead of herding
        onto the first-cheapest.  Default: no ledger."""


class NativeBackend(Backend):
    """The event loop's native worker pool seen as a routing target."""

    name = NATIVE

    def __init__(self, loop, tracker: OpCostTracker, *,
                 util_window_s: float = 0.25):
        self.loop = loop
        self.tracker = tracker
        self.util_window_s = util_window_s
        self.ledger = LoadLedger(lambda: max(1, loop.num_native_workers))
        self._util_cache = (0.0, -_INF)   # (value, measured_at)

    def can_run(self, op) -> bool:
        return True          # run_op resolves every op name locally

    def utilization(self) -> float:
        """Busy fraction of the pool over the recent window, in [0, 1].
        Memoized for a fraction of the window: route() calls this per op
        per entity, and the underlying BusyMeter scan takes every
        per-worker meter lock — rescanning inside one expand's fan-out
        would contend the native pool for identical answers."""
        val, at = self._util_cache
        now = time.monotonic()
        if now - at < self.util_window_s / 4.0:
            return val
        val = self.loop.t2_meter.utilization(
            workers=self.loop.num_native_workers,
            window_s=self.util_window_s)
        self._util_cache = (val, now)
        return val

    def estimate(self, op, payload_bytes: int) -> float:
        workers = max(1, self.loop.num_native_workers)
        base = self.tracker.estimate(op)
        return base * (1.0 + self.utilization()) \
            + self.ledger.backlog_s() / workers

    def queue_depth(self) -> int:
        return self.loop.queue1.qsize()

    def note_placed(self, op):
        self.ledger.add(self.tracker.estimate(op))


class RemoteBackend(Backend):
    """The κ remote-server pool seen as a routing target."""

    name = REMOTE

    def __init__(self, pool, tracker: OpCostTracker):
        self.pool = pool
        self.tracker = tracker
        self.ledger = LoadLedger(lambda: max(1, pool.live_count()))

    def can_run(self, op) -> bool:
        return self.pool.live_count() > 0

    def estimate(self, op, payload_bytes: int) -> float:
        live = self.pool.live_count()
        if not live:
            return _INF
        t = self.pool.transport
        queue_wait = (self.pool.pending_entities()
                      * self.pool.latency_estimate()) / live
        return t.cost(payload_bytes) + self.tracker.estimate(op) \
            + queue_wait + self.ledger.backlog_s() / live

    def queue_depth(self) -> int:
        return self.pool.pending_entities()

    def note_placed(self, op):
        self.ledger.add(self.tracker.estimate(op)
                        + self.pool.transport.service_time_s)


class StaticRouter:
    """Force every op onto one backend — ``dispatch="native"``, the
    all-native benchmark baseline (any backend name works)."""

    def __init__(self, backend: str = NATIVE):
        self.backend = backend
        self._lock = threading.Lock()
        self.chains_routed = 0    # guarded-by: _lock
        self.ops_routed = 0       # guarded-by: _lock

    def route(self, ops, start: int = 0, payload_bytes: int = 0) -> list:
        with self._lock:
            self.chains_routed += 1
            self.ops_routed += len(ops) - start
        return [self.backend] * len(ops)

    def stats(self) -> dict:
        with self._lock:
            return {"placements": {self.backend: self.ops_routed},
                    "handoffs": 0, "segments": self.chains_routed,
                    "chains_routed": self.chains_routed}


class BackendRouter:
    """Assigns each op of a chain to a backend by minimizing total
    estimated cost + ``handoff_s`` per backend switch (dynamic program
    over (op, backend); entry state is the native backend, because
    entities are always launched onto Queue_1)."""

    def __init__(self, backends: list[Backend], *,
                 overrides: dict | None = None,
                 handoff_s: float = 5e-4,
                 tracker: OpCostTracker | None = None,
                 health=None):
        self.backends = {b.name: b for b in backends}
        self.handoff_s = handoff_s
        self.overrides = validate_overrides(overrides,
                                            known=tuple(self.backends))
        self.tracker = tracker   # for payload propagation through chains
        # optional HealthRegistry (repro.query.health): an OPEN breaker
        # prices its backend at inf; otherwise costs scale by the
        # error-EWMA penalty (exactly 1.0 while healthy, so enabling
        # health tracking never perturbs a fault-free engine's routing).
        # The penalty applies to overridden costs too — a pinned regime
        # still drains away from a sick backend.
        self.health = health
        self._lock = threading.Lock()
        self.placements = {b.name: 0 for b in backends}   # guarded-by: _lock
        self.handoffs = 0         # guarded-by: _lock
        self.segments = 0         # guarded-by: _lock
        self.chains_routed = 0    # guarded-by: _lock

    # ----------------------------------------------------------- costing
    def cost(self, op, backend: str, payload_bytes: int = 0) -> float:
        """Estimated seconds of ``op`` on ``backend`` (inf when the
        backend cannot run it — overrides never bypass ``can_run``)."""
        b = self.backends[backend]
        if not b.can_run(op):
            return _INF
        if self.health is not None and not self.health.routable(backend):
            return _INF
        ov = self.overrides.get(op.name)
        if ov is not None and backend in ov:
            return self._health_scaled(backend, float(ov[backend]))
        return self._health_scaled(backend, b.estimate(op, payload_bytes))

    def _health_scaled(self, backend: str, base: float) -> float:
        if self.health is None:
            return base
        return base * self.health.penalty(backend)

    def cost_resident(self, op, backend: str, payload_bytes: int = 0) -> float:
        """Estimated seconds of ``op`` on ``backend`` when the previous
        op was ALSO placed there and the backend keeps payloads resident
        across consecutive ops (``resident_capable`` — the fused device
        segment).  Overrides pin the per-op cost in both regimes, so a
        forced cost regime is unaffected by fusion."""
        b = self.backends[backend]
        if not b.can_run(op):
            return _INF
        if self.health is not None and not self.health.routable(backend):
            return _INF
        ov = self.overrides.get(op.name)
        if ov is not None and backend in ov:
            return self._health_scaled(backend, float(ov[backend]))
        if not getattr(b, "resident_capable", False):
            return self._health_scaled(backend, b.estimate(op, payload_bytes))
        return self._health_scaled(backend,
                                   b.estimate_resident(op, payload_bytes))

    # ----------------------------------------------------------- routing
    def route(self, ops, start: int = 0,
              payload_bytes: int = 0) -> Optional[list]:
        """Backend name per op for ``ops[start:]`` (``route[:start]`` is
        filled with ``native`` — those ops already ran, e.g. a cache
        prefix hit resumes at ``start``).  Returns None for an empty
        tail (nothing to place)."""
        n = len(ops)
        if start >= n:
            return None
        names = list(self.backends)
        # dp over ops[start:]: cost to finish op i on backend b.  The
        # payload estimate is threaded THROUGH the chain: each op's cost
        # uses the previous op's observed output-size EWMA (falling back
        # to the entry payload), so a post-downscale remote op is costed
        # on the small intermediate, not the original blob.
        pb = float(payload_bytes)
        best: dict[str, float] = {}
        parent: list[dict[str, str]] = []
        for i, op in enumerate(ops[start:]):
            # two step prices per backend: "cold" (entering the backend
            # for this op — full estimate with wait/transfer/compile
            # terms) and "resident" (staying on a resident-capable
            # backend — marginal compute only).  For every backend that
            # is not resident_capable the two coincide, and the DP
            # degenerates to the original per-op recurrence.
            step = {b: self.cost(op, b, pb) for b in names}
            res_step = {b: self.cost_resident(op, b, pb) for b in names}
            if self.tracker is not None:
                pb = self.tracker.out_bytes(op, default=pb)
            if i == 0:
                # chains enter at native (Queue_1), so the first op is
                # always a cold entry — residency starts at op 2
                cur = {b: step[b] + (self.handoff_s if b != NATIVE else 0.0)
                       for b in names}
                parent.append({b: "" for b in names})
            else:
                cur, par = {}, {}
                for b in names:
                    stay = best[b] + res_step[b]
                    enter_from, enter_base = b, _INF
                    for p in names:
                        if p != b and best[p] < enter_base:
                            enter_base, enter_from = best[p], p
                    enter = enter_base + self.handoff_s + step[b]
                    if stay <= enter:
                        cur[b], par[b] = stay, b
                    else:
                        cur[b], par[b] = enter, enter_from
                parent.append(par)
            best = cur
        end = min(names, key=lambda b: best[b])
        chosen = [end]
        for par in reversed(parent[1:]):
            chosen.append(par[chosen[-1]])
        chosen.reverse()
        route = [NATIVE] * start + chosen
        # feedback + stats
        handoffs = sum(a != b for a, b in zip(chosen, chosen[1:]))
        for b_name, op in zip(chosen, ops[start:]):
            self.backends[b_name].note_placed(op)
        if self.health is not None:
            # a half-open breaker admits only a probe trickle: each
            # routed chain that touches the backend consumes one slot
            for b_name in set(chosen):
                self.health.note_probe(b_name)
        with self._lock:
            self.chains_routed += 1
            self.handoffs += handoffs
            self.segments += handoffs + 1
            for b_name in chosen:
                self.placements[b_name] += 1
        return route

    # ------------------------------------------------------------- stats
    def stats(self) -> dict:
        with self._lock:
            out = {
                "placements": dict(self.placements),
                "handoffs": self.handoffs,
                "segments": self.segments,
                "chains_routed": self.chains_routed,
            }
        out["queue_depths"] = {name: b.queue_depth()
                               for name, b in self.backends.items()}
        return out
