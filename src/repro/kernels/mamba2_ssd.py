"""Mamba2 SSD (state-space duality) Pallas TPU kernel.

Chunked scan: the SSM state h (headdim P x state N) sits in VMEM scratch
and flows across the sequential chunk axis.  Per chunk, the quadratic
intra-chunk term is two MXU matmuls on (chunk x chunk) tiles plus the
scalar-per-head decay matrix L (built from a cumulative sum in log
space), and the inter-chunk term contracts the carried state — this is
the blocked algorithm from the Mamba2 paper mapped onto MXU tiles.

chunk=128, P=64, N=64 -> per-step working set ~0.5 MB f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref,
                o_ref, hout_ref, h_scr, *, chunk):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = h0_ref[0, 0].astype(jnp.float32)

    xc = x_ref[0, 0].astype(jnp.float32)    # (c, P)
    dtc = dt_ref[0, 0].astype(jnp.float32)  # (c, 1)
    A = a_ref[0, 0].astype(jnp.float32)     # scalar (1,1)
    bc = b_ref[0, 0].astype(jnp.float32)    # (c, N)
    cc = c_ref[0, 0].astype(jnp.float32)    # (c, N)
    h = h_scr[...]                           # (P, N)

    la = jnp.cumsum(A * dtc[:, 0], axis=0)   # (c,)
    diff = la[:, None] - la[None, :]         # (c_t, c_s)
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.exp(jnp.where(tri, diff, -1e30))

    cb = jax.lax.dot_general(cc, bc, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (c, c)
    att = cb * L * dtc[:, 0][None, :]
    y = jax.lax.dot_general(att, xc, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (c, P)
    # inter-chunk: exp(la_t) C_t . h_in
    c_dec = cc * jnp.exp(la)[:, None]
    y = y + jax.lax.dot_general(c_dec, h, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # state update
    la_last = la[-1]
    wgt = jnp.exp(la_last - la) * dtc[:, 0]                        # (c,)
    h_scr[...] = jnp.exp(la_last) * h + jax.lax.dot_general(
        xc * wgt[:, None], bc, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    o_ref[0, 0] = y.astype(o_ref.dtype)

    @pl.when(ci == nc - 1)
    def _fin():
        hout_ref[0, 0] = h_scr[...].astype(hout_ref.dtype)


def mamba2_ssd_pallas(
    x: jax.Array,    # (B, T, H, P)
    dt: jax.Array,   # (B, T, H)
    A: jax.Array,    # (H,)
    Bm: jax.Array,   # (B, T, G, N)
    Cm: jax.Array,   # (B, T, G, N)
    D: jax.Array | None = None,
    state: jax.Array | None = None,  # (B, H, P, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    B, T, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    if state is None:
        state = jnp.zeros((B, H, P, N), jnp.float32)
    chunk = min(chunk, max(T, 8))
    pad = (-T) % chunk

    xt = x.transpose(0, 2, 1, 3)                       # (B,H,T,P)
    dtt = dt.transpose(0, 2, 1)[..., None]             # (B,H,T,1)
    Bt = jnp.repeat(Bm, rep, axis=2).transpose(0, 2, 1, 3)  # (B,H,T,N)
    Ct = jnp.repeat(Cm, rep, axis=2).transpose(0, 2, 1, 3)
    if pad:
        xt = jnp.pad(xt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        dtt = jnp.pad(dtt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        Bt = jnp.pad(Bt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        Ct = jnp.pad(Ct, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nc = (T + pad) // chunk
    A2 = A.reshape(H, 1).astype(jnp.float32)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    y, h_out = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nc * chunk, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(xt, dtt, A2, Bt, Ct, state)
    y = y[:, :, :T].transpose(0, 2, 1, 3)
    if D is not None:
        y = (y.astype(jnp.float32) + D[None, None, :, None] * x.astype(jnp.float32)).astype(x.dtype)
    return y, h_out
