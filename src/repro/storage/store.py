"""Entity blob store: in-memory LRU with optional disk spill (npz).

Plays the role of VDMS's TDB/visual-data store: decouples entity
payloads from metadata so the engine passes pointers, not pixels."""
from __future__ import annotations

import collections
import os
import threading

import numpy as np


class BlobStore:
    def __init__(self, capacity_bytes: int = 2 << 30,
                 spill_dir: str | None = None):
        self.capacity = capacity_bytes
        self.spill_dir = spill_dir
        if spill_dir:
            os.makedirs(spill_dir, exist_ok=True)
        self._lock = threading.Lock()
        self._mem: collections.OrderedDict[str, np.ndarray] = collections.OrderedDict()
        self._bytes = 0
        self.spills = 0
        self.hits = 0
        self.misses = 0

    def put(self, key: str, arr) -> None:
        arr = np.asarray(arr)
        with self._lock:
            if key in self._mem:
                self._bytes -= self._mem.pop(key).nbytes
            self._mem[key] = arr
            self._bytes += arr.nbytes
            self._evict_locked()

    def get(self, key: str):
        with self._lock:
            if key in self._mem:
                self._mem.move_to_end(key)
                self.hits += 1
                return self._mem[key]
        path = self._path(key)
        if path and os.path.exists(path):
            with self._lock:
                self.misses += 1
            arr = np.load(path)["a"]
            self.put(key, arr)
            return arr
        raise KeyError(key)

    def delete(self, key: str):
        with self._lock:
            if key in self._mem:
                self._bytes -= self._mem.pop(key).nbytes
        path = self._path(key)
        if path and os.path.exists(path):
            os.remove(path)

    def _evict_locked(self):
        while self._bytes > self.capacity and len(self._mem) > 1:
            key, arr = self._mem.popitem(last=False)
            self._bytes -= arr.nbytes
            path = self._path(key)
            if path:
                np.savez_compressed(path, a=arr)
                self.spills += 1

    def _path(self, key: str) -> str | None:
        if not self.spill_dir:
            return None
        safe = key.replace("/", "_")
        return os.path.join(self.spill_dir, safe + ".npz")

    def __contains__(self, key: str) -> bool:
        with self._lock:
            if key in self._mem:
                return True
        path = self._path(key)
        return bool(path and os.path.exists(path))
